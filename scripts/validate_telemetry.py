"""Validate a telemetry JSONL event stream against the export schema.

Usage: PYTHONPATH=src python scripts/validate_telemetry.py FILE [FILE...]

Exit 0 when every file parses and passes ``telemetry.validate_events``;
exit 1 (listing the errors) otherwise.  CI runs this over the scenario
sweep's ``--metrics-out`` output so a schema drift fails the build instead
of silently corrupting downstream dashboards.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.telemetry import read_jsonl, validate_events  # noqa: E402


def main(argv) -> int:
    """Validate each file; exit 0 only when all pass."""
    if not argv:
        print(__doc__)
        return 2
    failed = False
    for path in argv:
        try:
            events = read_jsonl(path)
        except Exception as e:
            print(f"{path}: UNREADABLE ({e})")
            failed = True
            continue
        errors = validate_events(events)
        if errors:
            failed = True
            print(f"{path}: {len(errors)} schema error(s)")
            for err in errors[:20]:
                print(f"  - {err}")
        else:
            kinds = {}
            for e in events:
                kinds[e["event"]] = kinds.get(e["event"], 0) + 1
            summary = ", ".join(f"{k}={n}" for k, n in sorted(kinds.items()))
            print(f"{path}: OK ({len(events)} events: {summary})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
