"""Paper-scale (M=500) claims validation — trimmed to the loads that decide
C1/C2/C3/C6.  Writes artifacts/bench/paper_scale.json."""
import os, sys, time, json
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
import numpy as np
from repro.core import Cluster, Rates, SimConfig, simulate_grid

cluster = Cluster(M=500, K=10)
rates = Rates(0.01, 0.005, 0.002)
cfg = SimConfig(T=24_000, warmup=6_000, route_mode="sequential")
loads = (0.3, 0.5, 0.7, 0.8, 0.9)
algos = ("balanced_pandas", "balanced_pandas_pod", "jsq_maxweight",
         "jsq_maxweight_pod", "jsq_priority", "fcfs")
out = {"M": 500, "K": 10, "T": cfg.T, "loads": list(loads), "dists": {}}
for dist in ("geometric", "lognormal"):
    import dataclasses
    c = dataclasses.replace(cfg, service_dist=dist)
    rows = {}
    for algo in algos:
        t0 = time.time()
        res = simulate_grid(algo, cluster, rates, list(loads), 3, c)
        t = np.asarray(res.mean_completion_norm)
        rows[algo] = {
            "mean": t.mean(0).tolist(),
            "sem": (t.std(0) / np.sqrt(t.shape[0])).tolist(),
            "drift": np.asarray(res.drift).mean(0).tolist(),
            "local_frac": np.asarray(res.locality_fractions)[..., 0].mean(0).tolist(),
        }
        print(f"[{dist}] {algo:22s} " + " ".join(f"{x:7.2f}" for x in rows[algo]["mean"]) + f"  ({time.time()-t0:.0f}s)", flush=True)
    out["dists"][dist] = rows

# ---------------------------------------------------------------------------
# C-HT: heavy traffic at the HONEST (fluid-LP) capacity edge, M in the
# hundreds.  With skewed Zipf placement the closed-form edge alpha*M*scale
# over-states capacity by ~1.5x at this scale; lam_cap is now the
# placement-aware LP optimum, so loads 0.90/0.95 of it are genuinely
# subcritical (both drifts must come back < 1.5 — at the old optimistic
# edge, "0.95" was really ~1.4x the true edge and diverged).  The GB-PANDAS
# delay ordering is asymptotic: Balanced-Pandas is heavy-traffic
# delay-optimal while JSQ-MaxWeight is not, so approaching the edge the
# BP/JSQ-MW mean-delay ratio must shrink toward 1 — that monotone trend is
# the finite-T observable we check (outright BP <= MW needs rho -> 1 and
# much longer runs than a validation script affords).
# ---------------------------------------------------------------------------
from repro.scenarios import SCENARIOS, realize
from repro.scenarios.capacity import uniform_edge

ht_cluster = Cluster(M=240, K=10)
ht_cfg = SimConfig(T=20_000, warmup=5_000, route_mode="sequential")
ht_loads = (0.90, 0.95)
ht_scen = SCENARIOS["zipf_hotspot"]
_, ht_cap = realize(ht_scen, ht_cluster, rates, ht_cfg.T)
ht_closed = uniform_edge(realize(ht_scen, ht_cluster, rates, ht_cfg.T)[0],
                         rates, ht_cfg.T)
print(f"[heavy-traffic] zipf_hotspot @ M={ht_cluster.M}: LP edge "
      f"{ht_cap:.3f} vs closed form {ht_closed:.3f} "
      f"({ht_cap / ht_closed:.3f}x)", flush=True)
ht_rows = {}
for algo in ("balanced_pandas", "jsq_maxweight"):
    t0 = time.time()
    res = simulate_grid(algo, ht_cluster, rates, list(ht_loads), 3, ht_cfg,
                        scenario=ht_scen)
    t = np.asarray(res.mean_completion_norm)
    ht_rows[algo] = {
        "mean": t.mean(0).tolist(),
        "sem": (t.std(0) / np.sqrt(t.shape[0])).tolist(),
        "drift": np.asarray(res.drift).mean(0).tolist(),
    }
    print(f"[heavy-traffic] {algo:22s} " +
          " ".join(f"{x:7.2f}" for x in ht_rows[algo]["mean"]) +
          f"  ({time.time()-t0:.0f}s)", flush=True)
bp = ht_rows["balanced_pandas"]["mean"]
mw = ht_rows["jsq_maxweight"]["mean"]
ratios = [b / max(m, 1e-9) for b, m in zip(bp, mw)]
drifts = (ht_rows["balanced_pandas"]["drift"]
          + ht_rows["jsq_maxweight"]["drift"])
subcritical = all(d < 1.5 for d in drifts)
trend = ratios[-1] < ratios[0]
ht_ok = subcritical and trend
out["heavy_traffic_edge"] = {
    "scenario": "zipf_hotspot", "M": ht_cluster.M, "K": ht_cluster.K,
    "T": ht_cfg.T, "loads": list(ht_loads),
    "lam_cap_lp": float(ht_cap), "lam_cap_closed_form": float(ht_closed),
    "algos": ht_rows, "bp_over_mw_ratio": ratios,
    "claim": ("all cells subcritical at the LP edge (drift < 1.5) and "
              "BP/JSQ-MW mean-delay ratio shrinks toward 1 as rho -> edge"),
    "subcritical": bool(subcritical), "trend_ok": bool(trend),
    "ok": bool(ht_ok),
}
print(f"[heavy-traffic] BP/JSQ-MW ratio " +
      " ".join(f"rho={l}: {r:.3f}" for l, r in zip(ht_loads, ratios)) +
      f"  subcritical={subcritical}  -> {'PASS' if ht_ok else 'FAIL'}",
      flush=True)

os.makedirs("artifacts/bench", exist_ok=True)
json.dump(out, open("artifacts/bench/paper_scale.json", "w"), indent=1)
print("WROTE artifacts/bench/paper_scale.json")
if not ht_ok:
    sys.exit("heavy-traffic ordering check FAILED (see above)")
