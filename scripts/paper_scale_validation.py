"""Paper-scale (M=500) claims validation — trimmed to the loads that decide
C1/C2/C3/C6.  Writes artifacts/bench/paper_scale.json."""
import os, sys, time, json
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
import numpy as np
from repro.core import Cluster, Rates, SimConfig, simulate_grid

cluster = Cluster(M=500, K=10)
rates = Rates(0.01, 0.005, 0.002)
cfg = SimConfig(T=24_000, warmup=6_000, route_mode="sequential")
loads = (0.3, 0.5, 0.7, 0.8, 0.9)
algos = ("balanced_pandas", "balanced_pandas_pod", "jsq_maxweight",
         "jsq_maxweight_pod", "jsq_priority", "fcfs")
out = {"M": 500, "K": 10, "T": cfg.T, "loads": list(loads), "dists": {}}
for dist in ("geometric", "lognormal"):
    import dataclasses
    c = dataclasses.replace(cfg, service_dist=dist)
    rows = {}
    for algo in algos:
        t0 = time.time()
        res = simulate_grid(algo, cluster, rates, list(loads), 3, c)
        t = np.asarray(res.mean_completion_norm)
        rows[algo] = {
            "mean": t.mean(0).tolist(),
            "sem": (t.std(0) / np.sqrt(t.shape[0])).tolist(),
            "drift": np.asarray(res.drift).mean(0).tolist(),
            "local_frac": np.asarray(res.locality_fractions)[..., 0].mean(0).tolist(),
        }
        print(f"[{dist}] {algo:22s} " + " ".join(f"{x:7.2f}" for x in rows[algo]["mean"]) + f"  ({time.time()-t0:.0f}s)", flush=True)
    out["dists"][dist] = rows
os.makedirs("artifacts/bench", exist_ok=True)
json.dump(out, open("artifacts/bench/paper_scale.json", "w"), indent=1)
print("WROTE artifacts/bench/paper_scale.json")
