"""Capacity-edge smoke check: the fluid LP + auto-extend warmup, CI-sized.

Usage: PYTHONPATH=src python scripts/check_capacity_edge.py [--out=DIR]

Runs in a couple of minutes on CPU and fails loudly (exit 1) when any of
the honest-capacity invariants breaks:

1. dispatch   — every uniform-placement registry scenario keeps the
   closed-form lam_cap BIT-FOR-BIT, padded == raw for all scenarios;
2. honesty    — every skewed-placement scenario's LP edge is strictly
   below the fleet-only closed form;
3. exactness  — the LP reproduces the hand-computable edge of a
   single-hot-triple catalog (3*alpha + (M-R)*gamma) to 1e-9;
4. auto-extend — a slow-mixing high-load run starts with windowed drift
   >= threshold and converges below it after extension; a fast-mixing run
   records zero extensions; an unmeasurable (NaN) drift reports NOT
   converged.

Writes ``capacity_edges.json`` (per-scenario LP vs closed-form table) and
``warmup_report.json`` to --out (default artifacts/capacity) for the CI
artifact upload.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    Cluster,
    Rates,
    SimConfig,
    simulate_auto_warmup,
)
from repro.scenarios import SCENARIOS, canonical_pad, realize  # noqa: E402
from repro.scenarios.build import ScenarioData  # noqa: E402
from repro.scenarios.capacity import (  # noqa: E402
    HAVE_SCIPY,
    fluid_edge,
    uniform_edge,
)
from repro.telemetry import TelemetryConfig, auto_extend_warmup  # noqa: E402

CLUSTER = Cluster(M=24, K=4)
RATES = Rates(0.05, 0.025, 0.01)
T = 2000


def check_registry(failures: list) -> dict:
    """LP vs closed form over the whole registry; returns the table."""
    pad = canonical_pad(CLUSTER)
    table = {}
    for name, spec in SCENARIOS.items():
        scen, cap = realize(spec, CLUSTER, RATES, T)
        closed = uniform_edge(scen, RATES, T)
        _, cap_p = realize(spec, CLUSTER, RATES, T, pad=pad)
        skewed = spec.placement.kind != "uniform"
        table[name] = {"lam_cap": cap, "closed_form": closed,
                       "ratio": cap / max(closed, 1e-12), "skewed": skewed}
        if abs(cap_p - cap) > 1e-9 * max(cap, 1.0):
            failures.append(f"{name}: padded {cap_p} != raw {cap}")
        if not skewed and cap != closed:
            failures.append(f"{name}: uniform placement but lam_cap {cap} "
                            f"!= closed form {closed} (must be bit-for-bit)")
        if skewed and not cap < closed:
            failures.append(f"{name}: skewed placement but LP edge {cap} "
                            f"not strictly below closed form {closed}")
        print(f"[capacity] {name:22s} lam_cap {cap:8.4f}  "
              f"closed {closed:8.4f}  ratio {cap / max(closed, 1e-12):.4f}"
              f"{'  (skewed)' if skewed else ''}", flush=True)
    return table


def check_exactness(failures: list):
    """Single-hot-triple catalog: LP == 3a + (M-R)g, hand-computable."""
    cl = Cluster(M=6, K=2)
    scen = ScenarioData(
        lam_shape=jnp.ones(T, jnp.float32),
        base_speed=jnp.ones(6, jnp.float32),
        win_start=jnp.zeros(0, jnp.int32),
        win_end=jnp.zeros(0, jnp.int32),
        win_mult=jnp.ones((0, 6, 3), jnp.float32),
        chunk_logits=jnp.zeros(1, jnp.float32),
        chunk_locals=jnp.asarray([[0, 1, 2]], jnp.int32),
    )
    want = 3 * RATES.alpha + 3 * RATES.gamma
    got = fluid_edge(scen, cl, RATES, T)
    print(f"[capacity] single-triple edge: LP {got:.6f} vs hand {want:.6f}",
          flush=True)
    if abs(got - want) > 1e-9:
        failures.append(f"single-triple LP {got} != hand-computed {want}")


def check_auto_extend(failures: list) -> dict:
    """Slow-mixing run extends and converges; fast-mixing never extends."""
    cl = Cluster(M=12, K=3)
    tcfg = TelemetryConfig()
    _, _, slow = simulate_auto_warmup(
        "balanced_pandas", cl, RATES, 0.93, jax.random.PRNGKey(1),
        cfg=SimConfig(T=6000, warmup=0), telemetry=tcfg)
    print(f"[auto-warmup] slow-mixing: drift {slow.drift0:.3f} -> "
          f"{slow.drift:.3f}, warmup 0 -> {slow.warmup} "
          f"({slow.extensions} extensions, converged={slow.converged})",
          flush=True)
    if not (slow.drift0 >= 1.05 and slow.extensions >= 1 and slow.converged
            and slow.drift < 1.05):
        failures.append(f"slow-mixing auto-extend misbehaved: {slow}")
    _, tele, fast = simulate_auto_warmup(
        "balanced_pandas", cl, RATES, 0.6, jax.random.PRNGKey(1),
        cfg=SimConfig(T=6000, warmup=1500), telemetry=tcfg)
    print(f"[auto-warmup] fast-mixing: drift {fast.drift:.3f}, "
          f"{fast.extensions} extensions, converged={fast.converged}",
          flush=True)
    if not (fast.extensions == 0 and fast.converged):
        failures.append(f"fast-mixing run extended or failed: {fast}")
    nan_rep = auto_extend_warmup(tele, tcfg, 6000, 6000)
    if nan_rep.converged or "UNMEASURABLE" not in nan_rep.note:
        failures.append(f"NaN drift not handled loudly: {nan_rep}")
    return {"slow_mixing": slow.fields(), "fast_mixing": fast.fields(),
            "nan_drift": nan_rep.fields()}


def main() -> int:
    """Run all checks; exit 0 only when every invariant holds."""
    out_dir = "artifacts/capacity"
    for a in sys.argv[1:]:
        if a.startswith("--out="):
            out_dir = a.split("=", 1)[1]
    if not HAVE_SCIPY:
        print("FAIL: scipy unavailable — the LP edge cannot be checked "
              "(capacity_edge would silently fall back to the closed form)")
        return 1
    failures: list = []
    table = check_registry(failures)
    check_exactness(failures)
    warmup = check_auto_extend(failures)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "capacity_edges.json"), "w") as f:
        json.dump({"M": CLUSTER.M, "K": CLUSTER.K, "T": T,
                   "rates": list(RATES), "scenarios": table}, f, indent=1)
    with open(os.path.join(out_dir, "warmup_report.json"), "w") as f:
        json.dump(warmup, f, indent=1)
    print(f"[capacity] wrote {out_dir}/capacity_edges.json and "
          f"warmup_report.json", flush=True)
    if failures:
        print("\nFAILED capacity-edge checks:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("capacity-edge smoke: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
