"""Validate arrival-log trace files against the versioned schema.

Checks each file (JSONL or packed-npz; repro.trace.format) with
``validate_log`` and prints a per-file verdict plus summary stats
(tasks, horizon, churn epochs, tenants).  CI's trace-replay-smoke leg
runs this on every synthesized trace artifact before replaying it.

Usage: PYTHONPATH=src python scripts/validate_trace.py TRACE [TRACE ...]
Exit 0 when every file validates, 1 otherwise.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.trace import load as load_log            # noqa: E402
from repro.trace import validate_log                # noqa: E402


def check(path: str) -> bool:
    """Load + schema-check one trace file, printing the verdict."""
    try:
        log = load_log(path)
    except Exception as e:
        print(f"[validate_trace] FAIL {path}: unreadable ({e})")
        return False
    errs = validate_log(log)
    if errs:
        for e in errs:
            print(f"[validate_trace] FAIL {path}: {e}")
        return False
    tenants = ("none" if log.tenant is None
               else str(int(log.tenant.max()) + 1))
    print(f"[validate_trace] ok   {path}: {log.n_tasks} tasks, "
          f"horizon {log.horizon:g}, {log.n_epochs} placement epoch(s), "
          f"tenants {tenants}, schema {log.schema}")
    return True


def main(paths) -> int:
    """Check every path; exit 0 only when all pass."""
    if not paths:
        print(__doc__)
        return 1
    return 0 if all([check(p) for p in paths]) else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
