"""Docstring lint: every public module and public function must say what
it is for.

Usage: python scripts/check_docs.py [PATH ...]

Walks the checked scope (default: the reproduction spine —
``src/repro/{core,scenarios,telemetry,trace,kernels}`` plus
``benchmarks`` and ``scripts``; pass paths to lint anything else),
parses each file with ``ast`` (no imports, so it is safe on any file
regardless of heavy dependencies), and fails listing every
public module / public top-level function / public method that has no
docstring, or whose docstring is a placeholder (< 8 characters).  Names
with a leading underscore are exempt, as are test files — tests document
themselves through their assertions.  CI runs this on every push: the
navigability docs (docs/ARCHITECTURE.md) lean on module docstrings as
the per-file source of truth, so a missing one is a build error, not a
style nit.

Exit 0 when clean; exit 1 listing ``path:line: kind name`` otherwise.
"""
import ast
import os
import sys

DEFAULT_SCOPE = ("src/repro/core", "src/repro/scenarios",
                 "src/repro/telemetry", "src/repro/trace",
                 "src/repro/kernels", "benchmarks", "scripts")
MIN_DOC = 8  # shorter than this is a placeholder, not documentation


def _public(name: str) -> bool:
    return not name.startswith("_")


def _has_doc(node) -> bool:
    doc = ast.get_docstring(node)
    return doc is not None and len(doc.strip()) >= MIN_DOC


def check_file(path: str):
    """Yield ``(line, kind, name)`` for every missing docstring in one
    file."""
    with open(path, encoding="utf-8") as f:
        try:
            tree = ast.parse(f.read(), filename=path)
        except SyntaxError as e:
            yield (e.lineno or 0, "unparseable", str(e))
            return
    if not _has_doc(tree):
        yield (1, "module", os.path.basename(path))
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _public(node.name) and not _has_doc(node):
                yield (node.lineno, "function", node.name)
        elif isinstance(node, ast.ClassDef) and _public(node.name):
            if not _has_doc(node):
                yield (node.lineno, "class", node.name)
            for sub in node.body:
                if (isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and _public(sub.name) and sub.name != "__init__"
                        and not _has_doc(sub)):
                    yield (sub.lineno, "method",
                           f"{node.name}.{sub.name}")


def iter_files(roots):
    """Python files under ``roots``, skipping tests and dunder caches."""
    for root in roots:
        if os.path.isfile(root):
            yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fn in sorted(filenames):
                if (fn.endswith(".py") and not fn.startswith("test_")
                        and fn != "conftest.py"):
                    yield os.path.join(dirpath, fn)


def main(argv) -> int:
    """Lint the scope; print findings and return a shell exit code."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    roots = argv or [os.path.join(repo, p) for p in DEFAULT_SCOPE]
    missing = []
    n_files = 0
    for path in iter_files(roots):
        n_files += 1
        rel = os.path.relpath(path, repo)
        missing += [(rel, line, kind, name)
                    for line, kind, name in check_file(path)]
    if missing:
        print(f"check_docs: {len(missing)} public def(s) without a "
              f"docstring across {n_files} files:")
        for rel, line, kind, name in missing:
            print(f"  {rel}:{line}: {kind} {name}")
        return 1
    print(f"check_docs: OK ({n_files} files, all public modules/"
          f"functions/classes documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
