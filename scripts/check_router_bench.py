"""Loose perf-regression gate over the BENCH_router.json trajectory.

Compares the LAST run (the datapoint CI just appended) against the most
recent EARLIER run with the same preset — i.e. the latest committed
datapoint — and fails if any algorithm's slots_per_s fell by more than
2x.  The 2x bar is deliberately loose: CI runners are noisy and the
Pallas interpreter's wall-clock jitters, so this gate only catches real
regressions (a kernel accidentally falling off the fused path, an
added host round-trip per slot), not scheduling noise.

Usage: python scripts/check_router_bench.py [BENCH_router.json]
Exit 0 on pass (or nothing to compare against), 1 on regression.
"""
import json
import os
import sys

FACTOR = 2.0


def main(path: str) -> int:
    """Gate the newest datapoint against the previous one (2x bar)."""
    with open(path) as f:
        data = json.load(f)
    runs = data.get("runs", [])
    if not runs:
        print(f"[check_router_bench] {path}: no runs — nothing to gate")
        return 0
    fresh = runs[-1]
    prior = [r for r in runs[:-1] if r.get("preset") == fresh.get("preset")]
    if not prior:
        print(f"[check_router_bench] no earlier '{fresh.get('preset')}' "
              "datapoint — nothing to gate against")
        return 0
    base = prior[-1]
    failed = False
    for algo, cur in fresh.get("throughput", {}).items():
        ref = base.get("throughput", {}).get(algo)
        if ref is None:
            print(f"[check_router_bench] {algo:22s} new algorithm, skipped")
            continue
        cur_s, ref_s = cur["slots_per_s"], ref["slots_per_s"]
        ratio = cur_s / max(ref_s, 1e-9)
        ok = cur_s * FACTOR >= ref_s
        mark = "ok  " if ok else "FAIL"
        print(f"[check_router_bench] {mark} {algo:22s} "
              f"{cur_s:12.0f} slots/s vs {ref_s:12.0f} committed "
              f"({ratio:5.2f}x, gate {1 / FACTOR:.2f}x)")
        failed |= not ok
    if failed:
        print(f"[check_router_bench] slots_per_s regressed past {FACTOR}x "
              f"vs the latest committed '{fresh.get('preset')}' datapoint "
              f"({base.get('date')})")
        return 1
    return 0


if __name__ == "__main__":
    default = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_router.json")
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else default))
