"""C2 mechanism ablation: full-BP tie-breaking policy decides the sign of
the BP-Pod vs BP medium-load comparison (EXPERIMENTS §Paper-claims)."""
import os, sys, json, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import numpy as np
from repro.core import Cluster, Rates, SimConfig, simulate_grid

cluster = Cluster(M=500, K=10)
rates = Rates(0.01, 0.005, 0.002)
cfg = SimConfig(T=24_000, warmup=6_000, route_mode="sequential")
loads = (0.5, 0.6, 0.7, 0.8)
out = {"loads": list(loads), "algos": {}}
for algo in ("balanced_pandas", "balanced_pandas_randomtie",
             "balanced_pandas_pod"):
    t0 = time.time()
    res = simulate_grid(algo, cluster, rates, list(loads), 3, cfg)
    t = np.asarray(res.mean_completion_norm)
    out["algos"][algo] = {
        "mean": t.mean(0).tolist(),
        "sem": (t.std(0)/np.sqrt(3)).tolist(),
        "local_frac": np.asarray(res.locality_fractions)[..., 0].mean(0).tolist()}
    print(f"{algo:28s} " + " ".join(f"{x:7.2f}" for x in out['algos'][algo]['mean'])
          + "   loc " + " ".join(f"{x:.2f}" for x in out['algos'][algo]['local_frac'])
          + f" ({time.time()-t0:.0f}s)", flush=True)
json.dump(out, open("artifacts/bench/tiebreak_ablation.json", "w"), indent=1)
print("WROTE artifacts/bench/tiebreak_ablation.json")
