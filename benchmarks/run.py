"""Benchmark orchestrator — one harness per paper figure/table + the
framework's complexity/roofline reports + the scenario sweep.  Prints a
``name,seconds,headline`` CSV summary at the end.

Usage:  PYTHONPATH=src python -m benchmarks.run [--preset=paper|smoke]
                                                [--only=suite1,suite2]
"""
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import balls_and_bins
import complexity
import fig2_exponential
import fig3_highload_exp
import fig4_fixedload_exp
import fig5_lognormal
import fig6_highload_logn
import fig7_fixedload_logn
import locality
import roofline_table
import router_bench
import scenarios as scenarios_suite
import trace_replay
from common import preset_from_argv


def _headline(name, out):
    try:
        if name.startswith("fig"):
            algos = out["algos"]
            bp = algos["balanced_pandas"]["mean"]
            pod = algos["balanced_pandas_pod"]["mean"]
            import numpy as np
            gain = float(np.nanmean((np.array(bp) - np.array(pod))
                                    / np.array(bp)))
            return f"BP-Pod vs BP mean-completion gain {gain:+.1%}"
        if name == "complexity":
            r = out["probes"][1]
            return (f"M={r['M']}: Pod probes {r['ratio']:.1%} of full "
                    f"(paper: 2.2%)")
        if name == "roofline":
            done = [r for r in out if isinstance(r, dict)
                    and "skipped" not in r]
            return f"{len(done)} cells"
        if name == "trace_replay":
            tp = out["throughput"]["trace_replay"]["tasks_per_s"]
            return (f"replay {tp:.0f} routed tasks/s = "
                    f"{out['speedup_vs_per_slot']:.1f}x per-slot path; "
                    f"trace_count {out['trace_count']}")
        if name == "router_bench":
            tp = out["throughput"]["balanced_pandas_pod"]
            bp_f = out["probe_quality"]["balanced_pandas_pod"]["flatness"]
            mw_f = out["probe_quality"]["jsq_maxweight_pod"]["flatness"]
            return (f"BP-Pod {tp['slots_per_s']:.0f} slots/s; regret "
                    f"flatness BP-Pod {bp_f:.2f} vs JSQ-MW-Pod {mw_f:.2f}")
        if name == "scenarios":
            import numpy as np
            rows = out["scenarios"]
            gaps = {n: (r["algos"]["balanced_pandas_pod"]["mean"]
                        - r["algos"]["balanced_pandas"]["mean"])
                    / max(r["algos"]["balanced_pandas"]["mean"], 1e-9)
                    for n, r in rows.items()}
            worst = max(rows, key=lambda n: rows[n]["sensitivity_d"])
            return (f"{len(rows)} scenarios; BP-Pod vs BP gap "
                    f"{np.mean(list(gaps.values())):+.1%} mean; "
                    f"d-sensitivity peaks at {worst} "
                    f"({rows[worst]['sensitivity_d']:+.1%})")
    except Exception:
        pass
    return ""


def main() -> None:
    preset = preset_from_argv()
    print(f"[benchmarks] preset={preset.name} M={preset.cluster.M} "
          f"K={preset.cluster.K} T={preset.cfg.T}")
    suites = [
        ("fig2_exponential", fig2_exponential.main),
        ("fig3_highload_exp", fig3_highload_exp.main),
        ("fig4_fixedload_exp", fig4_fixedload_exp.main),
        ("fig5_lognormal", fig5_lognormal.main),
        ("fig6_highload_logn", fig6_highload_logn.main),
        ("fig7_fixedload_logn", fig7_fixedload_logn.main),
        ("locality", locality.main),
        ("scenarios", scenarios_suite.main),
        ("trace_replay", trace_replay.main),
        ("router_bench", router_bench.main),
        ("complexity", complexity.main),
        ("balls_and_bins", balls_and_bins.main),
        ("roofline", roofline_table.main),
    ]
    only = [a.split("=", 1)[1] for a in sys.argv[1:]
            if a.startswith("--only=")]
    if only:
        wanted = {n for o in only for n in o.split(",") if n}
        unknown = wanted - {n for n, _ in suites}
        if unknown:
            raise SystemExit(f"--only: unknown suites {sorted(unknown)}")
        suites = [(n, fn) for n, fn in suites if n in wanted]
    summary = []
    for name, fn in suites:
        t0 = time.time()
        try:
            out = fn(preset)
            summary.append((name, time.time() - t0, _headline(name, out)))
        except Exception as e:  # keep the harness running
            summary.append((name, time.time() - t0, f"FAILED: {e}"))
            print(f"[benchmarks] {name} FAILED: {e}", file=sys.stderr)
    print("\nname,seconds,headline")
    for name, dt, head in summary:
        print(f"{name},{dt:.1f},{head}")


if __name__ == "__main__":
    main()
