"""Benchmark orchestrator — one harness per paper figure/table + the
framework's complexity/roofline reports + the scenario sweeps.  Prints a
``name,seconds,headline`` CSV summary at the end.

``--help`` output is generated from the suite registry (``SUITES``), so
it can never drift from what ``--only=`` accepts — CI smoke-checks that
every registered suite is named in it (tests/test_benchmarks_cli.py).
"""
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import balls_and_bins
import complexity
import fig2_exponential
import fig3_highload_exp
import fig4_fixedload_exp
import fig5_lognormal
import fig6_highload_logn
import fig7_fixedload_logn
import locality
import roofline_table
import router_bench
import scenarios as scenarios_suite
import trace_replay
from common import preset_from_argv

# The suite registry: (name, entry point taking a Preset, one-line help).
# --only= names, --help text, and the summary CSV all come from here.
SUITES = [
    ("fig2_exponential", fig2_exponential.main,
     "paper Fig 2: mean completion vs load, exponential service"),
    ("fig3_highload_exp", fig3_highload_exp.main,
     "paper Fig 3: high-load zoom, exponential service"),
    ("fig4_fixedload_exp", fig4_fixedload_exp.main,
     "paper Fig 4: fixed load, completion time vs d, exponential"),
    ("fig5_lognormal", fig5_lognormal.main,
     "paper Fig 5: mean completion vs load, heavy-tailed lognormal"),
    ("fig6_highload_logn", fig6_highload_logn.main,
     "paper Fig 6: high-load zoom, lognormal service"),
    ("fig7_fixedload_logn", fig7_fixedload_logn.main,
     "paper Fig 7: fixed load, completion time vs d, lognormal"),
    ("locality", locality.main,
     "local/rack/remote service-fraction table per algorithm"),
    ("scenarios", scenarios_suite.main,
     "registry scenario sweep at fixed load + BP-Pod d-sensitivity"),
    ("grid", scenarios_suite.grid_main,
     "one-program mega-sweep: scenario x load x seed grid per policy, "
     "mean +/- CI columns -> BENCH_sweep.json"),
    ("trace_replay", trace_replay.main,
     "production-day trace replay throughput vs the per-slot path"),
    ("router_bench", router_bench.main,
     "routing throughput + probe-quality d-sweep -> BENCH_router.json"),
    ("complexity", complexity.main,
     "probe-count complexity table (Pod probes vs full-sweep O(M))"),
    ("balls_and_bins", balls_and_bins.main,
     "power-of-d balls-and-bins sanity check vs theory"),
    ("roofline", roofline_table.main,
     "kernel roofline / occupancy table (TPU; skips cells on CPU)"),
]

FLAGS = [
    ("--preset=smoke|quick|paper",
     "cluster scale + run length (default quick; CI uses smoke)"),
    ("--only=s1,s2", "run only the named suites (see list above)"),
    ("--grid", "shorthand for --only=grid"),
    ("--scenarios=n1,n2", "scenarios/grid: restrict the scenario set; "
     "'a+b' composes registry entries ad hoc"),
    ("--metrics-out=FILE", "scenarios/grid: collect in-jit telemetry and "
     "write the JSONL event stream to FILE"),
    ("--grid-loads=0.45,0.9", "grid: override the preset's load axis"),
    ("--grid-seeds=N", "grid: override the preset's Monte-Carlo seeds"),
    ("--policies=p1,p2", "grid: override the policy set"),
    ("--loop-baseline=K", "grid: loop K scenarios on the pre-sweep path "
     "for the wall-clock comparison (0 skips; default 3)"),
]


def usage() -> str:
    """--help text generated from SUITES + FLAGS (cannot drift)."""
    lines = [
        "usage: PYTHONPATH=src python -m benchmarks.run [flags]",
        "",
        "Runs the registered benchmark suites (all of them by default)",
        "and prints a name,seconds,headline CSV summary.",
        "",
        "suites:",
    ]
    for name, _, help_line in SUITES:
        lines.append(f"  {name:20s} {help_line}")
    lines.append("")
    lines.append("flags:")
    for flag, help_line in FLAGS:
        lines.append(f"  {flag:24s} {help_line}")
    return "\n".join(lines)


def _headline(name, out):
    try:
        if name.startswith("fig"):
            algos = out["algos"]
            bp = algos["balanced_pandas"]["mean"]
            pod = algos["balanced_pandas_pod"]["mean"]
            import numpy as np
            gain = float(np.nanmean((np.array(bp) - np.array(pod))
                                    / np.array(bp)))
            return f"BP-Pod vs BP mean-completion gain {gain:+.1%}"
        if name == "complexity":
            r = out["probes"][1]
            return (f"M={r['M']}: Pod probes {r['ratio']:.1%} of full "
                    f"(paper: 2.2%)")
        if name == "roofline":
            done = [r for r in out if isinstance(r, dict)
                    and "skipped" not in r]
            return f"{len(done)} cells"
        if name == "trace_replay":
            tp = out["throughput"]["trace_replay"]["tasks_per_s"]
            return (f"replay {tp:.0f} routed tasks/s = "
                    f"{out['speedup_vs_per_slot']:.1f}x per-slot path; "
                    f"trace_count {out['trace_count']}")
        if name == "router_bench":
            tp = out["throughput"]["balanced_pandas_pod"]
            bp_f = out["probe_quality"]["balanced_pandas_pod"]["flatness"]
            mw_f = out["probe_quality"]["jsq_maxweight_pod"]["flatness"]
            return (f"BP-Pod {tp['slots_per_s']:.0f} slots/s; regret "
                    f"flatness BP-Pod {bp_f:.2f} vs JSQ-MW-Pod {mw_f:.2f}")
        if name == "grid":
            op = next(iter(out["one_program"].values()))
            head = (f"{len(out['scenarios'])}x{len(out['loads'])}x"
                    f"{out['seeds']} grid; {op['cells']} cells/policy; "
                    f"trace_count +{op['trace_count']}")
            if out.get("speedup_per_cell"):
                head += f"; {out['speedup_per_cell']:.1f}x vs looped"
            return head
        if name == "scenarios":
            import numpy as np
            rows = out["scenarios"]
            gaps = {n: (r["algos"]["balanced_pandas_pod"]["mean"]
                        - r["algos"]["balanced_pandas"]["mean"])
                    / max(r["algos"]["balanced_pandas"]["mean"], 1e-9)
                    for n, r in rows.items()}
            worst = max(rows, key=lambda n: rows[n]["sensitivity_d"])
            return (f"{len(rows)} scenarios; BP-Pod vs BP gap "
                    f"{np.mean(list(gaps.values())):+.1%} mean; "
                    f"d-sensitivity peaks at {worst} "
                    f"({rows[worst]['sensitivity_d']:+.1%})")
    except Exception:
        pass
    return ""


def main() -> None:
    """Parse flags, run the selected suites, print the CSV summary."""
    if "--help" in sys.argv[1:] or "-h" in sys.argv[1:]:
        print(usage())
        return
    preset = preset_from_argv()
    print(f"[benchmarks] preset={preset.name} M={preset.cluster.M} "
          f"K={preset.cluster.K} T={preset.cfg.T}")
    only = [a.split("=", 1)[1] for a in sys.argv[1:]
            if a.startswith("--only=")]
    if "--grid" in sys.argv[1:]:
        only.append("grid")
    suites = [(n, fn) for n, fn, _ in SUITES]
    if only:
        wanted = {n for o in only for n in o.split(",") if n}
        unknown = wanted - {n for n, _ in suites}
        if unknown:
            raise SystemExit(f"--only: unknown suites {sorted(unknown)}; "
                             f"see --help")
        suites = [(n, fn) for n, fn in suites if n in wanted]
    summary = []
    for name, fn in suites:
        t0 = time.time()
        try:
            out = fn(preset)
            summary.append((name, time.time() - t0, _headline(name, out)))
        except Exception as e:  # keep the harness running
            summary.append((name, time.time() - t0, f"FAILED: {e}"))
            print(f"[benchmarks] {name} FAILED: {e}", file=sys.stderr)
    print("\nname,seconds,headline")
    for name, dt, head in summary:
        print(f"{name},{dt:.1f},{head}")


if __name__ == "__main__":
    main()
