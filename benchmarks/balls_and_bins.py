"""Balls-and-bins power-of-d (paper §I): max load ~ log n/log log n for
d=1 vs ~ log log n/log d for d>=2."""
import jax
import numpy as np

from common import save_artifact
from repro.core.ballsbins import max_load, theory_d, theory_d1


def main(preset=None):
    """Measure max bin load vs the paper's d=1 / d>=2 asymptotics."""
    rows = []
    for n in (256, 1024, 4096):
        keys = jax.random.split(jax.random.PRNGKey(n), 5)
        row = {"n": n, "theory_d1": theory_d1(n)}
        for d in (1, 2, 4):
            loads = [int(max_load(k, n, d)) for k in keys]
            row[f"d{d}_mean"] = float(np.mean(loads))
            if d > 1:
                row[f"theory_d{d}"] = theory_d(n, d)
        rows.append(row)
    save_artifact("balls_and_bins", {"rows": rows})
    print("\n== Balls & bins: empirical max load vs theory ==")
    print(f"{'n':>6} {'d=1':>6} {'~ln n/lnln n':>12} {'d=2':>6} "
          f"{'~lnln n/ln2':>11} {'d=4':>6}")
    for r in rows:
        print(f"{r['n']:>6} {r['d1_mean']:>6.1f} {r['theory_d1']:>12.2f} "
              f"{r['d2_mean']:>6.1f} {r['theory_d2']:>11.2f} "
              f"{r['d4_mean']:>6.1f}")
    return rows


if __name__ == "__main__":
    main()
