"""Routing-throughput benchmark + probe-quality d-sweep -> BENCH_router.json.

Two measurements, both appended as one datapoint to the repo-root
``BENCH_router.json`` trajectory (PR-over-PR perf tracking — the ROADMAP's
fused-router megakernel work will be judged against this file):

  1. **Throughput**: steady-state wall-clock of the jit'd simulator on the
     batched (Pallas-kernel) routing path, reported as simulated slots/s
     and routing decisions/s per algorithm.  The first call pays the
     compile; the timed call rides the jit cache, so the number tracks the
     kernel + scan step itself.

  2. **Probe quality vs d** (telemetry): mean routing regret (chosen score
     minus the O(M) oracle's) for BP-Pod and JSQ-MW-Pod across probe
     budgets d in {3, 8, 16}.  The paper's d-sensitivity claim, as a
     direct observable: BP-Pod's regret curve is flat in d; JSQ-MW-Pod's
     is not.  ``flatness`` = regret(d=3) / regret(d=16) — near 1 is flat.

Usage: PYTHONPATH=src python benchmarks/router_bench.py [--preset=smoke]
"""
import dataclasses
import os
import time

import numpy as np

from common import append_trajectory, preset_from_argv

from repro.core import (PodSpec, simulate_grid, simulate_grid_with_telemetry,
                        trace_count)
from repro.telemetry import TelemetryConfig, probe_summary

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_router.json")

ALGOS = ("balanced_pandas", "balanced_pandas_pod", "jsq_maxweight_pod")
D_SWEEP = (PodSpec(1, 2), PodSpec(2, 6), PodSpec(4, 12))


def _throughput(preset) -> dict:
    """Slots/s and routing decisions/s on the batched kernel path."""
    cfg = dataclasses.replace(preset.cfg, route_mode="batched")
    out = {}
    for algo in ALGOS:
        args = (algo, preset.cluster, preset.rates, [preset.fixed_load],
                preset.n_seeds, cfg)
        res = simulate_grid(*args)                      # compile + warm
        np.asarray(res.mean_tasks_in_system)            # block
        t0 = time.time()
        res = simulate_grid(*args)
        decisions = float(np.asarray(res.route_decisions).sum())
        np.asarray(res.mean_tasks_in_system)
        wall = time.time() - t0
        slots = cfg.T * preset.n_seeds
        out[algo] = {
            "wall_s": wall,
            "slots_per_s": slots / max(wall, 1e-9),
            "route_decisions_per_s": decisions / max(wall, 1e-9),
        }
        print(f"[router_bench] {algo:22s} {slots / max(wall, 1e-9):12.0f} "
              f"slots/s  {decisions / max(wall, 1e-9):12.0f} decisions/s")
    return out


def _probe_quality(preset) -> dict:
    """Mean probe rank / regret per (pod algo, d) — flat in d for BP-Pod."""
    tcfg = TelemetryConfig(sojourns=False)   # probes only: cheaper
    out = {}
    for algo in ("balanced_pandas_pod", "jsq_maxweight_pod"):
        by_d = {}
        for pod in D_SWEEP:
            _, tele = simulate_grid_with_telemetry(
                algo, preset.cluster, preset.rates, [preset.fixed_load],
                preset.n_seeds, preset.cfg, pod=pod, telemetry=tcfg)
            by_d[pod.d] = probe_summary(tele)
        r_small = by_d[min(by_d)]["mean_regret"]
        r_large = by_d[max(by_d)]["mean_regret"]
        flat = (r_small / max(r_large, 1e-12)
                if r_small is not None and r_large is not None else None)
        out[algo] = {"by_d": {str(d): s for d, s in by_d.items()},
                     "flatness": flat}
        cells = "  ".join(
            f"d={d}: {s['mean_regret']:.4f}" if s["mean_regret"] is not None
            else f"d={d}: n/a" for d, s in sorted(by_d.items()))
        msg = f"[router_bench] regret {algo:22s} {cells}"
        if flat is not None:
            msg += f"  flatness(d3/d16) {flat:.2f}"
        print(msg)
    return out


def _append_datapoint(point: dict, path: str = None) -> None:
    """Corruption-safe append to the BENCH_router.json trajectory (shared
    helper: common.append_trajectory; trace_replay.py reuses this too)."""
    append_trajectory(path or BENCH_PATH, point)


def main(preset=None):
    """Run both measurements and append the BENCH_router.json datapoint."""
    p = preset or preset_from_argv()
    throughput = _throughput(p)
    probes = _probe_quality(p)
    point = {
        "date": time.strftime("%Y-%m-%d"),
        "preset": p.name,
        "M": p.cluster.M, "K": p.cluster.K,
        "T": p.cfg.T, "n_seeds": p.n_seeds, "load": p.fixed_load,
        "route_mode": "batched",
        "trace_count": trace_count(),
        "throughput": throughput,
        "probe_quality": probes,
    }
    _append_datapoint(point)
    print(f"[router_bench] appended datapoint -> {BENCH_PATH}")
    return point


if __name__ == "__main__":
    main()
