"""Load-balancing complexity: the paper's O(M) -> O(1) claim (§IV-C).

Two measurements:
  1. Probes per decision (information the central scheduler must fetch):
     Balanced-Pandas touches M workloads per routing decision;
     Balanced-Pandas-Pod touches 3 + d.  For M=500, d=8: 2.2%.
  2. Wall-clock routing throughput of the two kernel-backed router paths
     (weighted_argmin vs pod_route) as M grows — the O(M) scan's cost per
     decision grows linearly while Pod routing stays flat.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import preset_from_argv, save_artifact


def probes_table():
    """Pod probes vs full-sweep O(M) per routing decision (paper SIV-C)."""
    rows = []
    for M in (100, 500, 1000, 4000, 16000):
        full = M
        pod = 3 + 8
        rows.append({"M": M, "full_probes": full, "pod_probes": pod,
                     "ratio": pod / full})
    return rows


def kernel_throughput(Ms=(128, 512, 2048, 8192), B=256, iters=20):
    """us per routing decision: pod_route vs full weighted_argmin."""
    from repro.kernels import pod_route, weighted_argmin
    inv = jnp.array([25.0, 50.0, 125.0], jnp.float32)
    out = []
    key = jax.random.PRNGKey(0)
    for M in Ms:
        ks = jax.random.split(key, 5)
        W = jax.random.uniform(ks[0], (M,)) * 100
        cls = jax.random.randint(ks[1], (B, M), 0, 3)
        ci = jax.random.randint(ks[2], (B, 11), 0, M)
        cc = jax.random.randint(ks[3], (B, 11), 0, 3)
        cv = jnp.ones((B, 11), bool)

        full = lambda: weighted_argmin(W, cls, inv)[0].block_until_ready()
        pod = lambda: pod_route(W, ci, cc, cv, inv)[0].block_until_ready()
        full();  pod()                         # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            full()
        t_full = (time.perf_counter() - t0) / iters / B * 1e6
        t0 = time.perf_counter()
        for _ in range(iters):
            pod()
        t_pod = (time.perf_counter() - t0) / iters / B * 1e6
        out.append({"M": M, "full_us_per_decision": t_full,
                    "pod_us_per_decision": t_pod,
                    "speedup": t_full / t_pod})
    return out


def main(preset=None):
    """Print + save the probe-complexity and kernel-throughput tables."""
    probes = probes_table()
    thr = kernel_throughput()
    out = {"probes": probes, "kernel_throughput": thr}
    save_artifact("complexity", out)
    print("\n== Complexity: probes per routing decision (paper §IV-C) ==")
    print(f"{'M':>7} {'full O(M)':>10} {'Pod O(1)':>9} {'fraction':>9}")
    for r in probes:
        print(f"{r['M']:>7} {r['full_probes']:>10} {r['pod_probes']:>9} "
              f"{r['ratio']:>8.1%}")
    print("\n== Router kernel wall-clock (interpret mode, CPU) ==")
    print(f"{'M':>7} {'full us/dec':>12} {'pod us/dec':>11} {'speedup':>8}")
    for r in thr:
        print(f"{r['M']:>7} {r['full_us_per_decision']:>12.2f} "
              f"{r['pod_us_per_decision']:>11.2f} {r['speedup']:>8.1f}x")
    return out


if __name__ == "__main__":
    main()
