"""Fig 4 — fixed high load where BP & JSQ-MW are delay-optimal (exponential)."""
from common import ALGO_LABELS, preset_from_argv, print_table, run_figure


def main(preset=None):
    """Reproduce Fig 4 (completion vs d at fixed load)."""
    p = preset or preset_from_argv()
    out = run_figure(p, (p.fixed_load,), "geometric", "fig4_fixedload_exp")
    print_table(out)
    return out


if __name__ == "__main__":
    main()
