"""Roofline table from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
  compute term    = analytic_flops_computed / (chips x 197 TFLOP/s bf16)
  memory term     = analytic_hbm_bytes      / (chips x 819 GB/s)
  collective term = per-chip wire bytes (trip-weighted HLO walk) / 50 GB/s
  dominant        = argmax of the three
  useful ratio    = MODEL_FLOPS(6ND | 2ND) / computed FLOPs
  roofline frac   = ideal-compute time / dominant-term time
                    (the §Perf score: 1.0 == useful work runs at peak)

Analytic FLOPs/bytes are used because XLA cost_analysis counts scan bodies
once (see roofline/hlo.py); the XLA flat numbers are retained in the JSON
artifacts for transparency.
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRY = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def hint(row) -> str:
    """One-line optimization lever for a cell's dominant term."""
    dom = row["dominant"]
    fam = row.get("family", "")
    if dom == "collective":
        if fam == "moe":
            return ("overlap the EP combine all-reduce with expert GEMMs; "
                    "or cut capacity_factor")
        return ("shrink TP degree / move layers to DP; overlap the TP "
                "all-reduce with the following GEMM")
    if dom == "memory":
        if row["shape"].startswith(("decode", "long")):
            return "quantize KV cache to int8 and widen batch per chip"
        return "raise microbatch size (fewer param re-reads per step)"
    if row["useful_ratio"] < 0.6:
        return ("recover wasted compute: causal block skipping in flash "
                "attention / lower MoE capacity factor / trim head padding")
    return "increase per-chip batch or sequence to amortize weights"


def build_rows(dry_dir=DRY):
    """Load per-cell dry-run JSON artifacts into table rows."""
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        rec = json.load(open(path))
        if "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "skipped": rec["skipped"]})
            continue
        n = rec["n_devices"]
        comp = rec["analytic"]["flops_computed"] / n / PEAK_FLOPS
        mem = rec["analytic"]["hbm_bytes"] / n / HBM_BW
        coll = rec["collectives"]["total_wire_bytes"] / LINK_BW
        terms = {"compute": comp, "memory": mem, "collective": coll}
        dom = max(terms, key=terms.get)
        # decode is memory-bound by nature: score against the bytes floor
        # (weights-touched + KV per token); train/prefill against ideal
        # compute at peak.
        if rec["shape"].startswith(("decode", "long")):
            ideal = mem
        else:
            ideal = rec["model_flops"] / n / PEAK_FLOPS
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "family": rec.get("family", ""),
            "compute_s": comp, "memory_s": mem, "collective_s": coll,
            "dominant": dom,
            "useful_ratio": rec["model_flops"] / rec["analytic"]["flops_computed"],
            "roofline_frac": ideal / max(terms[dom], 1e-12),
            "model_flops": rec["model_flops"],
            "args_gb": rec["memory"]["argument_size_in_bytes"] / 1e9,
            "compile_s": rec["timings"]["compile_s"],
        })
    return rows


def render(rows, mesh="pod") -> str:
    """Markdown roofline table for one mesh size."""
    out = [f"### Roofline — {mesh} mesh (256 chips)" if mesh == "pod" else
           f"### Roofline — multi-pod mesh (512 chips)"]
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | useful | roofline | next lever |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | {r['skipped'][:60]}… |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} | {hint(r)} |")
    return "\n".join(out)


def main(preset=None):
    """Render the roofline tables (skips cleanly with no artifacts)."""
    rows = build_rows()
    if not rows:
        print("(no dry-run artifacts yet — run scripts/run_dryrun_sweep.sh)")
        return []
    done = [r for r in rows if "skipped" not in r]
    print(f"\n== Roofline table: {len(done)} compiled cells, "
          f"{len(rows) - len(done)} documented skips ==")
    for mesh in ("pod", "multipod"):
        print(render(rows, mesh))
    from common import save_artifact
    save_artifact("roofline", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
