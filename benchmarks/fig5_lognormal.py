"""Fig 5 — mean completion vs load (log-normal service)."""
from common import ascii_plot, preset_from_argv, print_table, run_figure


def main(preset=None):
    """Reproduce Fig 5 via the shared run_figure harness."""
    p = preset or preset_from_argv()
    out = run_figure(p, p.loads, "lognormal", "fig5_lognormal")
    print_table(out)
    print(ascii_plot(out))
    return out


if __name__ == "__main__":
    main()
