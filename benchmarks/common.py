"""Shared benchmark harness: presets, grid runner, ASCII plots, artifacts."""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    ALGORITHMS,
    Cluster,
    Rates,
    SimConfig,
    simulate_grid,
)
from repro.telemetry import format_clip_warning  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

ALGO_LABELS = {
    "fcfs": "FCFS",
    "jsq_priority": "JSQ-Priority",
    "jsq_maxweight": "JSQ-MaxWeight",
    "jsq_maxweight_pod": "JSQ-MaxWeight-Pod (d'=12)",
    "balanced_pandas": "Balanced-Pandas",
    "balanced_pandas_pod": "Balanced-Pandas-Pod (d=8)",
}


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    cluster: Cluster
    rates: Rates
    cfg: SimConfig
    loads: tuple
    high_loads: tuple
    fixed_load: float
    n_seeds: int


# CI-sized: small fleet, short runs — exercises every code path in seconds.
SMOKE = Preset(
    name="smoke",
    cluster=Cluster(M=40, K=4),
    rates=Rates(0.05, 0.025, 0.01),
    cfg=SimConfig(T=3_000, warmup=800, route_mode="batched"),
    loads=(0.5, 0.8),
    high_loads=(0.8,),
    fixed_load=0.8,
    n_seeds=1,
)

QUICK = Preset(
    name="quick",
    cluster=Cluster(M=100, K=10),
    rates=Rates(0.04, 0.02, 0.008),
    cfg=SimConfig(T=12_000, warmup=3_000, route_mode="sequential"),
    loads=(0.3, 0.5, 0.7, 0.8, 0.9, 0.95),
    high_loads=(0.85, 0.9, 0.95),
    fixed_load=0.9,
    n_seeds=2,
)

# paper §V scale: 500 servers, 10 racks of 50; finer slots (1% of local
# service time) so the discrete-time slotting approximates continuous time.
PAPER = Preset(
    name="paper",
    cluster=Cluster(M=500, K=10),
    rates=Rates(0.01, 0.005, 0.002),
    cfg=SimConfig(T=40_000, warmup=10_000, route_mode="sequential"),
    loads=(0.3, 0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95),
    high_loads=(0.85, 0.9, 0.95),
    fixed_load=0.9,
    n_seeds=4,
)


def preset_from_argv() -> Preset:
    if "--preset=paper" in sys.argv or "paper" in sys.argv[1:]:
        return PAPER
    if "--preset=smoke" in sys.argv or "smoke" in sys.argv[1:]:
        return SMOKE
    return QUICK


def run_figure(preset: Preset, loads, service_dist: str, name: str,
               algos=ALGORITHMS) -> dict:
    """Mean task completion time (units of mean local service) per algo x
    load; the harness behind every fig2-fig7 reproduction."""
    cfg = dataclasses.replace(preset.cfg, service_dist=service_dist)
    rows = {}
    timing = {}
    clip_cells = []
    for algo in algos:
        t0 = time.time()
        res = simulate_grid(algo, preset.cluster, preset.rates, list(loads),
                            preset.n_seeds, cfg)
        t = np.asarray(res.mean_completion_norm)       # [seeds, loads]
        drift = np.asarray(res.drift)
        clip = np.asarray(res.clip_fraction).mean(axis=0)
        rows[algo] = {
            "mean": t.mean(axis=0).tolist(),
            "sem": (t.std(axis=0) / max(np.sqrt(t.shape[0]), 1)).tolist(),
            "drift": drift.mean(axis=0).tolist(),
            "locality": np.asarray(res.locality_fractions).mean(axis=0).tolist(),
            "clip_fraction": clip.tolist(),
        }
        clip_cells += [(f"{name}/{algo}@rho={l}", float(c))
                       for l, c in zip(loads, clip)]
        timing[algo] = time.time() - t0
    warn = format_clip_warning(clip_cells)
    if warn:
        print(warn)
    out = {"figure": name, "preset": preset.name, "loads": list(loads),
           "service_dist": service_dist, "algos": rows,
           "wall_s": timing}
    save_artifact(name, out)
    return out


def save_artifact(name: str, obj: dict):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1)


def ascii_plot(out: dict, width: int = 64, height: int = 16,
               logy: bool = True) -> str:
    """Completion time vs load, one glyph per algorithm."""
    loads = out["loads"]
    glyphs = "BPMJQF"
    series = {}
    for g, (algo, row) in zip(glyphs, reversed(list(out["algos"].items()))):
        series[g] = (algo, np.array(row["mean"]))
    allv = np.concatenate([v for _, v in series.values()])
    allv = allv[np.isfinite(allv) & (allv > 0)]
    lo, hi = allv.min(), allv.max()
    f = np.log if logy else (lambda x: x)
    span = max(f(hi) - f(lo), 1e-9)
    grid = [[" "] * width for _ in range(height)]
    for g, (algo, v) in series.items():
        for i, (x, y) in enumerate(zip(loads, v)):
            if not np.isfinite(y) or y <= 0:
                continue
            col = int((x - loads[0]) / max(loads[-1] - loads[0], 1e-9)
                      * (width - 1))
            row = int((f(y) - f(lo)) / span * (height - 1))
            grid[height - 1 - row][col] = g
    lines = ["".join(r) for r in grid]
    legend = "  ".join(f"{g}={ALGO_LABELS[a]}" for g, (a, _) in series.items())
    hdr = (f"mean completion time (x mean local service), "
           f"{'log' if logy else 'lin'} scale {lo:.2f}..{hi:.2f}; "
           f"load {loads[0]}..{loads[-1]}")
    return "\n".join([hdr] + lines + [legend])


def print_table(out: dict):
    loads = out["loads"]
    print(f"\n== {out['figure']} ({out['preset']} preset, "
          f"{out['service_dist']} service) ==")
    print(f"{'algorithm':28s} " + " ".join(f"rho={l:<5}" for l in loads))
    for algo, row in out["algos"].items():
        cells = []
        for m, d in zip(row["mean"], row["drift"]):
            cells.append(f"{m:8.2f}{'*' if d > 1.5 else ' '}")
        print(f"{ALGO_LABELS[algo]:28s} " + " ".join(cells))
    print("(* = unstable: tasks-in-system still growing at end of run)")
