"""Shared benchmark harness: presets, grid runner, ASCII plots, artifacts."""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    ALGORITHMS,
    Cluster,
    Rates,
    SimConfig,
    simulate_grid,
)
from repro.telemetry import format_clip_warning  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

ALGO_LABELS = {
    "fcfs": "FCFS",
    "jsq_priority": "JSQ-Priority",
    "jsq_maxweight": "JSQ-MaxWeight",
    "jsq_maxweight_pod": "JSQ-MaxWeight-Pod (d'=12)",
    "balanced_pandas": "Balanced-Pandas",
    "balanced_pandas_pod": "Balanced-Pandas-Pod (d=8)",
}


@dataclasses.dataclass(frozen=True)
class Preset:
    """One benchmark scale: cluster + rates + run length + sweep axes."""
    name: str
    cluster: Cluster
    rates: Rates
    cfg: SimConfig
    loads: tuple
    high_loads: tuple
    fixed_load: float
    n_seeds: int
    # mega-sweep grid axes (benchmarks/scenarios.py grid_main): the
    # one-program registry sweep runs scenario x grid_loads x grid_seeds
    # per policy; grid_seeds are the Monte-Carlo replications behind the
    # mean +/- CI columns.
    grid_loads: tuple = (0.45, 0.7, 0.9)
    grid_seeds: int = 4


# CI-sized: small fleet, short runs — exercises every code path in seconds.
SMOKE = Preset(
    name="smoke",
    cluster=Cluster(M=40, K=4),
    rates=Rates(0.05, 0.025, 0.01),
    cfg=SimConfig(T=3_000, warmup=800, route_mode="batched"),
    loads=(0.5, 0.8),
    high_loads=(0.8,),
    fixed_load=0.8,
    n_seeds=1,
    grid_loads=(0.45, 0.7, 0.9),
    grid_seeds=4,
)

QUICK = Preset(
    name="quick",
    cluster=Cluster(M=100, K=10),
    rates=Rates(0.04, 0.02, 0.008),
    cfg=SimConfig(T=12_000, warmup=3_000, route_mode="sequential"),
    loads=(0.3, 0.5, 0.7, 0.8, 0.9, 0.95),
    high_loads=(0.85, 0.9, 0.95),
    fixed_load=0.9,
    n_seeds=2,
    grid_loads=(0.3, 0.5, 0.7, 0.9),
    grid_seeds=8,
)

# paper §V scale: 500 servers, 10 racks of 50; finer slots (1% of local
# service time) so the discrete-time slotting approximates continuous time.
PAPER = Preset(
    name="paper",
    cluster=Cluster(M=500, K=10),
    rates=Rates(0.01, 0.005, 0.002),
    cfg=SimConfig(T=40_000, warmup=10_000, route_mode="sequential"),
    loads=(0.3, 0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95),
    high_loads=(0.85, 0.9, 0.95),
    fixed_load=0.9,
    n_seeds=4,
    grid_loads=(0.3, 0.5, 0.7, 0.8, 0.9, 0.95),
    grid_seeds=8,
)


def preset_from_argv() -> Preset:
    """Resolve --preset=smoke|quick|paper from argv (default quick)."""
    if "--preset=paper" in sys.argv or "paper" in sys.argv[1:]:
        return PAPER
    if "--preset=smoke" in sys.argv or "smoke" in sys.argv[1:]:
        return SMOKE
    return QUICK


def run_figure(preset: Preset, loads, service_dist: str, name: str,
               algos=ALGORITHMS) -> dict:
    """Mean task completion time (units of mean local service) per algo x
    load; the harness behind every fig2-fig7 reproduction."""
    cfg = dataclasses.replace(preset.cfg, service_dist=service_dist)
    rows = {}
    timing = {}
    clip_cells = []
    for algo in algos:
        t0 = time.time()
        res = simulate_grid(algo, preset.cluster, preset.rates, list(loads),
                            preset.n_seeds, cfg)
        t = np.asarray(res.mean_completion_norm)       # [seeds, loads]
        drift = np.asarray(res.drift)
        clip = np.asarray(res.clip_fraction).mean(axis=0)
        rows[algo] = {
            "mean": t.mean(axis=0).tolist(),
            "sem": (t.std(axis=0) / max(np.sqrt(t.shape[0]), 1)).tolist(),
            "drift": drift.mean(axis=0).tolist(),
            "locality": np.asarray(res.locality_fractions).mean(axis=0).tolist(),
            "clip_fraction": clip.tolist(),
        }
        clip_cells += [(f"{name}/{algo}@rho={l}", float(c))
                       for l, c in zip(loads, clip)]
        timing[algo] = time.time() - t0
    warn = format_clip_warning(clip_cells)
    if warn:
        print(warn)
    out = {"figure": name, "preset": preset.name, "loads": list(loads),
           "service_dist": service_dist, "algos": rows,
           "wall_s": timing}
    save_artifact(name, out)
    return out


def auto_warmup_fields(tele, tcfg, T: int, warmup: int, policy=None):
    """Run the drift-aware auto-extend warmup loop on collected telemetry
    and return ``(WarmupReport, row_fields)`` for benchmark rows / JSONL
    manifests (warmup_realized, warmup_converged, post-extension drift...).

    Pure post-processing on window sums — the run is NOT repeated and a
    fast-mixing cell (drift already below threshold) records zero
    extensions.  A NaN drift comes back converged=False with a loud
    ``warmup_note`` (unmeasurable is never "converged").  Prints the note,
    once per offending cell, so table readers see it without opening the
    manifest."""
    from repro.telemetry import auto_extend_warmup
    report = auto_extend_warmup(tele, tcfg, T, warmup, policy=policy)\
        if policy is not None else auto_extend_warmup(tele, tcfg, T, warmup)
    if report.note:
        print(f"  [auto-warmup] {report.note}")
    return report, report.fields()


def save_artifact(name: str, obj: dict):
    """Dump one benchmark's result dict to ``artifacts/bench/<name>.json``."""
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1)


# two-sided 95% Student-t critical values by degrees of freedom (1..30;
# larger samples use the normal 1.96) — a table so the CI columns never
# depend on scipy (optional at runtime: the fluid-LP capacity edge uses
# it when present and falls back to the closed form when not)
_T95 = (12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
        2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101,
        2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052,
        2.048, 2.045, 2.042)


def mean_ci(x, axis=0):
    """Mean and 95% confidence half-width over ``axis`` (Student t on the
    standard error; NaN cells are dropped per-position).

    Returns ``(mean, ci)`` arrays with ``axis`` reduced.  ``n == 1``
    yields ci = NaN (a single replication has no spread estimate) — the
    mega-sweep's mean +/- CI columns come from here, so the grid presets
    keep ``grid_seeds >= 4``.
    """
    x = np.asarray(x, np.float64)
    n = np.sum(np.isfinite(x), axis=axis)
    mean = np.nanmean(np.where(np.isfinite(x), x, np.nan), axis=axis)
    sd = np.nanstd(np.where(np.isfinite(x), x, np.nan), axis=axis, ddof=1)
    tcrit = np.where(n > 1, np.take(np.asarray(_T95 + (1.96,)),
                                    np.minimum(np.maximum(n - 1, 1),
                                               len(_T95) + 1) - 1), np.nan)
    with np.errstate(invalid="ignore", divide="ignore"):
        ci = np.where(n > 1, tcrit * sd / np.sqrt(np.maximum(n, 1)), np.nan)
    return mean, ci


def append_trajectory(path: str, point: dict) -> None:
    """Append one datapoint to a ``{"schema": 1, "runs": [...]}`` perf
    trajectory file (BENCH_router.json / BENCH_sweep.json).

    A corrupt/unreadable trajectory is NEVER silently clobbered: the bad
    file is preserved at ``<path>.bad`` and the append fails loudly — perf
    history is the whole point of these files; losing one quietly on a
    truncated write or a merge-conflict marker defeats PR-over-PR
    tracking.
    """
    data = {"schema": 1, "runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            bad = path + ".bad"
            os.replace(path, bad)
            raise RuntimeError(
                f"{path} is corrupt or unreadable ({e}); moved it to {bad} "
                "instead of overwriting the perf trajectory — inspect/"
                "restore it, then re-run") from e
        if not isinstance(data.get("runs"), list):
            bad = path + ".bad"
            os.replace(path, bad)
            raise RuntimeError(
                f"{path} parsed but has no 'runs' list; moved it to {bad} "
                "instead of overwriting the perf trajectory")
    data["runs"].append(point)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def ascii_plot(out: dict, width: int = 64, height: int = 16,
               logy: bool = True) -> str:
    """Completion time vs load, one glyph per algorithm."""
    loads = out["loads"]
    glyphs = "BPMJQF"
    series = {}
    for g, (algo, row) in zip(glyphs, reversed(list(out["algos"].items()))):
        series[g] = (algo, np.array(row["mean"]))
    allv = np.concatenate([v for _, v in series.values()])
    allv = allv[np.isfinite(allv) & (allv > 0)]
    lo, hi = allv.min(), allv.max()
    f = np.log if logy else (lambda x: x)
    span = max(f(hi) - f(lo), 1e-9)
    grid = [[" "] * width for _ in range(height)]
    for g, (algo, v) in series.items():
        for i, (x, y) in enumerate(zip(loads, v)):
            if not np.isfinite(y) or y <= 0:
                continue
            col = int((x - loads[0]) / max(loads[-1] - loads[0], 1e-9)
                      * (width - 1))
            row = int((f(y) - f(lo)) / span * (height - 1))
            grid[height - 1 - row][col] = g
    lines = ["".join(r) for r in grid]
    legend = "  ".join(f"{g}={ALGO_LABELS[a]}" for g, (a, _) in series.items())
    hdr = (f"mean completion time (x mean local service), "
           f"{'log' if logy else 'lin'} scale {lo:.2f}..{hi:.2f}; "
           f"load {loads[0]}..{loads[-1]}")
    return "\n".join([hdr] + lines + [legend])


def print_table(out: dict):
    """Completion-time table for one figure dict (drift-starred cells)."""
    loads = out["loads"]
    print(f"\n== {out['figure']} ({out['preset']} preset, "
          f"{out['service_dist']} service) ==")
    print(f"{'algorithm':28s} " + " ".join(f"rho={l:<5}" for l in loads))
    for algo, row in out["algos"].items():
        cells = []
        for m, d in zip(row["mean"], row["drift"]):
            # NaN drift = UNMEASURABLE, flagged '!' — never shown as a
            # clean (converged) cell
            mark = "!" if d != d else ("*" if d > 1.5 else " ")
            cells.append(f"{m:8.2f}{mark}")
        print(f"{ALGO_LABELS[algo]:28s} " + " ".join(cells))
    print("(* = unstable: tasks-in-system still growing at end of run; "
          "! = drift unmeasurable — treat as NOT converged)")
