"""Fig 7 — fixed high load (log-normal)."""
from common import ALGO_LABELS, preset_from_argv, print_table, run_figure


def main(preset=None):
    """Reproduce Fig 7 (completion vs d at fixed load, lognormal)."""
    p = preset or preset_from_argv()
    out = run_figure(p, (p.fixed_load,), "lognormal", "fig7_fixedload_logn")
    print_table(out)
    return out


if __name__ == "__main__":
    main()
