"""Scenario sweep — the paper's algorithms under the scenario registry.

For every registered scenario (heterogeneous fleets, bursty / diurnal /
flash traffic, Zipf placement — repro.scenarios) this suite runs
Balanced-Pandas, Balanced-Pandas-Pod and JSQ-MaxWeight-Pod at the preset's
fixed load and reports mean task completion time, plus BP-Pod's
*sensitivity to d*: the paper's claim is that d barely matters (d=8 probes
recover the O(M) policy); scenarios show where that stops being true.

sensitivity_d = (mean_T[d=3] - mean_T[d=16]) / mean_T[d=16]
  ~0   -> the scenario is insensitive to the probe budget (paper regime)
  >>0  -> small candidate sets hurt; locality/heterogeneity makes extra
         probes valuable.

One-compile sweep: every scenario is realized against the registry-wide
canonical pad (scenarios.canonical_pad) with one shared a_max, so the jit'd
simulator step compiles once per (algo, pod) and the other scenarios ride
the cache — the per-scenario recompile used to dominate smoke wall-clock.
``--scenarios=name1,name2`` restricts the sweep (CI runs one natively-padded
and one natively-max-shaped scenario).  A ``+`` inside a name composes
registry scenarios on the fly (``--scenarios=slow_rack+flash_crowd`` runs
scenarios.compose of the two): the registry pad reserves pairwise window
headroom, so ad-hoc pairs stay on the registry's compiled signature (the
shared a_max is widened over the selection when a composition's traffic
peak exceeds the registry's).

``--metrics-out=FILE`` turns on the in-jit telemetry collectors
(repro.telemetry; one shared TelemetryConfig keeps the one-compile
property) and writes the full JSONL event stream — per-cell run manifest,
per-window rows, histograms, sojourn percentiles — to FILE.  Cells then
also report windowed drift (telemetry-ring upgrade of the half2/half1
ratio), sojourn p50/p95/p99, and pod probe quality (mean rank / routing
regret vs the O(M) oracle — the observable behind the paper's
d-sensitivity claim).
"""
import os
import sys
import time

import numpy as np

from common import (Preset, append_trajectory, auto_warmup_fields, mean_ci,
                    preset_from_argv, save_artifact)

from repro.core import (PodSpec, simulate_grid, simulate_grid_with_telemetry,
                        simulate_sweep, sweep_grid, trace_count)
from repro.scenarios import SCENARIOS, canonical_a_max, canonical_pad, compose
from repro.telemetry import (TelemetryConfig, cell_view, format_clip_warning,
                             probe_summary, run_manifest,
                             sojourn_percentiles, to_events, write_jsonl)

BENCH_SWEEP_PATH = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_sweep.json")

ALGOS = ("balanced_pandas", "balanced_pandas_pod", "jsq_maxweight_pod")

# d-sensitivity probe budgets for BP-Pod: (rack, remote) splits keeping the
# paper's 1:3 flavor; d = 3, 8 (paper), 16.
D_SWEEP = (PodSpec(1, 2), PodSpec(2, 6), PodSpec(4, 12))


def _metrics_out_path():
    for a in sys.argv[1:]:
        if a.startswith("--metrics-out="):
            return a.split("=", 1)[1]
    return None


def _mean_T(preset: Preset, algo: str, scenario, pod=None,
            pad=None, a_max=None, tcfg=None, sink=None, label=None) -> dict:
    """scenario: a registered name or a Scenario (ad-hoc composition).

    With ``tcfg`` the run collects telemetry: the returned row gains
    drift_windowed / sojourn / probe fields and the cell's JSONL events are
    appended to ``sink`` (a list)."""
    t0 = time.time()
    if tcfg is None:
        res = simulate_grid(algo, preset.cluster, preset.rates,
                            [preset.fixed_load], preset.n_seeds, preset.cfg,
                            pod=pod, scenario=scenario, pad=pad, a_max=a_max)
        tele = None
    else:
        res, tele = simulate_grid_with_telemetry(
            algo, preset.cluster, preset.rates, [preset.fixed_load],
            preset.n_seeds, preset.cfg, pod=pod, scenario=scenario, pad=pad,
            a_max=a_max, telemetry=tcfg)
    t = np.asarray(res.mean_completion_norm)       # [seeds, 1]
    row = {
        "mean": float(np.nanmean(t)),
        "sem": float(np.nanstd(t) / max(np.sqrt(t.shape[0]), 1)),
        "drift": float(np.asarray(res.drift).mean()),
        "local_frac": float(np.asarray(res.locality_fractions)[..., 0].mean()),
        "clip_fraction": float(np.asarray(res.clip_fraction).mean()),
    }
    if tele is not None:
        cfg = preset.cfg
        # drift-aware auto-extend warmup: push the measurement boundary
        # forward over the collected windows until the tail's drift drops
        # below threshold (pure post-processing — the run is not repeated);
        # rows and manifests record the REALIZED warmup and verdict, and a
        # NaN drift is carried as "unmeasured", never as converged
        _, wfields = auto_warmup_fields(tele, tcfg, cfg.T, cfg.warmup)
        row.update(wfields)
        row["sojourn"] = sojourn_percentiles(tele, tcfg)
        if "note" in row["sojourn"]:
            print(f"[scenarios] NOTE {label}/{algo}: "
                  f"{row['sojourn']['note']}")
        row["probe"] = probe_summary(tele)
        if sink is not None:
            sink.extend(to_events(tele, tcfg, cfg.T, cfg.warmup, run_manifest(
                suite="scenarios", scenario=label, algo=algo,
                d=(pod.d if pod is not None else None),
                load=preset.fixed_load, seeds=preset.n_seeds, T=cfg.T,
                warmup=cfg.warmup, wall_s=time.time() - t0,
                trace_count=trace_count(), **wfields)))
    return row


def _selected_scenarios() -> dict:
    only = [a.split("=", 1)[1] for a in sys.argv[1:]
            if a.startswith("--scenarios=")]
    if not only:
        return dict(SCENARIOS)
    wanted = [n for o in only for n in o.split(",") if n]
    parts = {p for n in wanted for p in (n.split("+") if "+" in n else (n,))}
    unknown = parts - set(SCENARIOS)
    if unknown:
        raise SystemExit(f"--scenarios: unknown {sorted(unknown)}; "
                         f"registered: {sorted(SCENARIOS)}")
    # a `+` composes registry scenarios ad hoc (scenarios.compose)
    return {n: (compose(*n.split("+")) if "+" in n else SCENARIOS[n])
            for n in wanted}


def main(preset=None):
    """Fixed-load scenario sweep + BP-Pod d-sensitivity (see module doc)."""
    p = preset or preset_from_argv()
    selected = _selected_scenarios()
    # canonical padding over the FULL registry (not just the selection):
    # any filtered run shares the same compiled signature as the full sweep
    # (pairwise + compositions ride the registry pad's compose headroom);
    # the shared a_max widens over ad-hoc compositions whose traffic peak
    # exceeds the registry's.
    pad = canonical_pad(p.cluster)
    extra = [s for n, s in selected.items() if n not in SCENARIOS]
    # a 3+-way ad-hoc composition can union more windows than the pairwise
    # (COMPOSE_DEPTH=2) headroom reserves; widen only then (the run leaves
    # the registry's shared signature, but still compiles once for its own
    # selection) — the library spelling is canonical_pad(compose_depth=N)
    need = max((len(s.fleet.windows) for s in extra), default=0)
    if need > pad.n_windows:
        pad = pad._replace(n_windows=need)
    a_max = canonical_a_max(p.cluster, p.rates, p.cfg, p.fixed_load,
                            scenarios=list(SCENARIOS.values()) + extra)
    metrics_out = _metrics_out_path()
    tcfg = TelemetryConfig() if metrics_out else None
    sink = [] if metrics_out else None
    rows = {}
    for name, scen in selected.items():
        t0 = time.time()
        label = name if isinstance(name, str) else str(name)
        row = {"description": scen.description, "algos": {}}
        d_means = {pod.d: _mean_T(p, "balanced_pandas_pod", scen, pod=pod,
                                  pad=pad, a_max=a_max, tcfg=tcfg,
                                  sink=sink, label=label)
                   for pod in D_SWEEP}
        for algo in ALGOS:
            # the d=8 sweep cell IS BP-Pod at its default PodSpec(2, 6)
            # with the same seeds — reuse instead of re-simulating
            row["algos"][algo] = (d_means[8] if algo == "balanced_pandas_pod"
                                  else _mean_T(p, algo, scen, pad=pad,
                                               a_max=a_max, tcfg=tcfg,
                                               sink=sink, label=label))
        d_small, d_large = min(d_means), max(d_means)
        row["d_sweep"] = {str(d): m for d, m in d_means.items()}
        row["sensitivity_d"] = (
            (d_means[d_small]["mean"] - d_means[d_large]["mean"])
            / max(d_means[d_large]["mean"], 1e-9))
        row["wall_s"] = time.time() - t0
        rows[name] = row

        bp = row["algos"]["balanced_pandas"]["mean"]
        pod_t = row["algos"]["balanced_pandas_pod"]["mean"]
        print(f"[scenarios] {name:16s} BP {bp:8.2f}  BP-Pod {pod_t:8.2f} "
              f"({(pod_t - bp) / max(bp, 1e-9):+.1%})  "
              f"JSQ-MW-Pod {row['algos']['jsq_maxweight_pod']['mean']:8.2f}  "
              f"d-sens {row['sensitivity_d']:+.1%}  "
              f"[{row['wall_s']:.1f}s]")
        if tcfg is not None:
            regret = {d: m["probe"]["mean_regret"]
                      for d, m in d_means.items()}
            print("            probe regret (BP-Pod): " + "  ".join(
                f"d={d}: {r:.4f}" if r is not None else f"d={d}: n/a"
                for d, r in sorted(regret.items())))

    out = {"figure": "scenarios", "preset": p.name, "load": p.fixed_load,
           "algos": list(ALGOS), "d_values": [pod.d for pod in D_SWEEP],
           "scenarios": rows}
    save_artifact("scenarios", out)
    _print_table(out)
    # loud clip surfacing: silent arrival truncation biases measured loads
    warn = format_clip_warning(
        [(f"{n}/{a}", r.get("clip_fraction", 0.0))
         for n, row in rows.items() for a, r in row["algos"].items()])
    if warn:
        print(warn)
    if metrics_out:
        write_jsonl(metrics_out, sink, append=False)
        print(f"[scenarios] wrote {len(sink)} telemetry events "
              f"-> {metrics_out}")
    return out


def _print_table(out: dict):
    print(f"\n== scenario sweep ({out['preset']} preset, "
          f"load {out['load']}) ==")
    print(f"{'scenario':16s} {'BP':>9s} {'BP-Pod':>9s} {'JSQ-MW-Pod':>11s} "
          f"{'d-sens':>8s}  {'BP-Pod local%':>13s}")
    for name, row in out["scenarios"].items():
        a = row["algos"]
        def cell(r):
            # prefer the windowed (telemetry-ring, post-auto-extend) drift
            # when collected; a NaN drift is UNMEASURABLE and flagged '!'
            # — never silently shown as a clean, converged cell (the old
            # fallthrough to r['drift'] hid exactly that)
            d = r.get("drift_windowed")
            if d is None:
                d = r["drift"]
            if d != d:
                return f"{r['mean']:8.2f}!"
            return f"{r['mean']:8.2f}{'*' if d > 1.5 else ' '}"
        print(f"{name:16s} {cell(a['balanced_pandas'])} "
              f"{cell(a['balanced_pandas_pod'])} "
              f"{cell(a['jsq_maxweight_pod']):>11s} "
              f"{row['sensitivity_d']:+7.1%}  "
              f"{a['balanced_pandas_pod']['local_frac']:12.1%}")
    print("(* = unstable: tasks-in-system still growing after the "
          "(auto-extended) warmup; ! = drift unmeasurable — treat as NOT "
          "converged.  Load calibration is placement-AWARE: lam_cap is the "
          "fluid-LP optimum, so zipf/adversarial cells at load < 1 are "
          "genuinely subcritical — see repro.scenarios docstring.  BENCH "
          "rows recorded before the LP landed used the optimistic closed "
          "form for skewed placements and ran at a higher true load.)")


# ---------------------------------------------------------------------------
# One-program mega-sweep: the full scenario x load x seed grid per policy
# (core.simulate_sweep), with mean +/- CI columns and a looped-baseline
# wall-clock comparison -> BENCH_sweep.json
# ---------------------------------------------------------------------------

GRID_POLICIES = ("balanced_pandas", "balanced_pandas_pod",
                 "jsq_maxweight_pod")


def _flag(name: str, default=None):
    """Value of ``--<name>=...`` from argv, or ``default``."""
    for a in sys.argv[1:]:
        if a.startswith(f"--{name}="):
            return a.split("=", 1)[1]
    return default


def _grid_axes(preset: Preset):
    """(scenario labels, loads, n_seeds, policies) after flag overrides."""
    selected = _selected_scenarios()
    loads = _flag("grid-loads")
    loads = (tuple(float(x) for x in loads.split(",") if x) if loads
             else tuple(preset.grid_loads))
    n_seeds = int(_flag("grid-seeds", preset.grid_seeds))
    pols = _flag("policies")
    pols = (tuple(p for p in pols.split(",") if p) if pols
            else GRID_POLICIES)
    unknown = set(pols) - set(ALGOS) - {"jsq_maxweight", "jsq_priority",
                                        "fcfs"}
    if unknown:
        raise SystemExit(f"--policies: unknown {sorted(unknown)}")
    return selected, loads, n_seeds, pols


def grid_main(preset=None):
    """Run the registry benchmark grid as ONE compiled program per policy.

    For every policy, the full scenario x load x seed grid is stacked
    (scenarios.stack_scenarios), vmapped, and — on multi-device hosts —
    shard_mapped across devices by ``core.simulate_sweep``; the report
    carries mean +/- 95% CI columns over the seed replications.  A looped
    baseline (the pre-mega-sweep per-scenario ``simulate_grid`` loop) is
    timed on a subset for the wall-clock comparison, and the datapoint is
    appended (corruption-safely) to ``BENCH_sweep.json``.
    """
    p = preset or preset_from_argv()
    selected, loads, n_seeds, policies = _grid_axes(p)
    labels = list(selected)
    scen_specs = list(selected.values())
    pad = canonical_pad(p.cluster)
    need = max((len(s.fleet.windows) for n, s in selected.items()
                if n not in SCENARIOS), default=0)
    if need > pad.n_windows:
        pad = pad._replace(n_windows=need)
    _, _, _, a_max = sweep_grid(p.cluster, p.rates, p.cfg, loads,
                                scenarios=scen_specs, pad=pad)
    metrics_out = _metrics_out_path()
    tcfg = TelemetryConfig() if metrics_out else None
    sink = [] if metrics_out else None
    n_cells = len(labels) * len(loads) * n_seeds
    print(f"[grid] {len(labels)} scenarios x {len(loads)} loads x "
          f"{n_seeds} seeds = {n_cells} cells per policy "
          f"(a_max={a_max}, policies: {', '.join(policies)})")

    cells = {}
    one_program = {}
    for algo in policies:
        tc0 = trace_count()
        t0 = time.time()
        names, res, tele = simulate_sweep(
            algo, p.cluster, p.rates, loads, n_seeds, p.cfg,
            scenarios=scen_specs, pad=pad, a_max=a_max, telemetry=tcfg)
        t = np.asarray(res.mean_completion_norm)    # [S, seeds, L]
        wall = time.time() - t0
        one_program[algo] = {"wall_s": wall, "cells": n_cells,
                             "cells_per_s": n_cells / max(wall, 1e-9),
                             "trace_count": trace_count() - tc0}
        mean, ci = mean_ci(t, axis=1)               # [S, L]
        drift = np.asarray(res.drift).mean(axis=1)
        clip = np.asarray(res.clip_fraction).mean(axis=1)
        cells[algo] = {
            lbl: {str(l): {"mean": float(mean[s, j]), "ci": float(ci[s, j]),
                           "drift": float(drift[s, j]),
                           "clip_fraction": float(clip[s, j])}
                  for j, l in enumerate(loads)}
            for s, lbl in enumerate(labels)}
        print(f"[grid] {algo:20s} {wall:7.1f}s "
              f"({one_program[algo]['cells_per_s']:.1f} cells/s, "
              f"trace_count +{one_program[algo]['trace_count']})")
        if tcfg is not None:
            _grid_cell_events(p, algo, labels, loads, n_seeds, tele, tcfg,
                              sink, wall)

    looped = _looped_baseline(p, policies[0], scen_specs, labels, loads,
                              n_seeds, pad, a_max, tcfg)
    speedup = None
    if looped:
        looped["cells_per_s_one_program"] = \
            one_program[policies[0]]["cells_per_s"]
        speedup = (one_program[policies[0]]["cells_per_s"]
                   / max(looped["cells_per_s"], 1e-9))
        print(f"[grid] looped baseline ({looped['n_scenarios']} scenarios, "
              f"{looped['cells']} cells): {looped['wall_s']:.1f}s -> "
              f"one-program speedup {speedup:.1f}x per cell")

    out = {"figure": "grid", "preset": p.name, "loads": list(loads),
           "seeds": n_seeds, "policies": list(policies),
           "scenarios": labels, "cells": cells,
           "one_program": one_program, "looped_baseline": looped,
           "speedup_per_cell": speedup}
    save_artifact("grid", out)
    _print_grid_table(out)
    warn = format_clip_warning(
        [(f"{algo}/{lbl}@rho={l}", c["clip_fraction"])
         for algo, rows in cells.items() for lbl, by_load in rows.items()
         for l, c in by_load.items()])
    if warn:
        print(warn)
    if metrics_out:
        write_jsonl(metrics_out, sink, append=False)
        print(f"[grid] wrote {len(sink)} telemetry events -> {metrics_out}")
    append_trajectory(BENCH_SWEEP_PATH, {
        "date": time.strftime("%Y-%m-%d"),
        "preset": p.name, "M": p.cluster.M, "K": p.cluster.K,
        "T": p.cfg.T, "route_mode": p.cfg.route_mode,
        "grid": {"scenarios": len(labels), "loads": list(loads),
                 "seeds": n_seeds, "cells_per_policy": n_cells},
        "policies": list(policies),
        "one_program": one_program,
        "looped_baseline": looped,
        "speedup_per_cell": speedup,
    })
    print(f"[grid] appended datapoint -> {BENCH_SWEEP_PATH}")
    return out


def _looped_baseline(p, algo, scen_specs, labels, loads, n_seeds, pad,
                     a_max, tcfg=None):
    """Time the pre-mega-sweep path — a Python loop of per-scenario
    ``simulate_grid`` calls — on ``--loop-baseline=K`` scenarios (default
    min(3, all); 0 skips).  Same pad / a_max / keys / telemetry config as
    the one-program sweep, so each baseline cell is bit-identical to the
    stacked cell (tests/test_sweep.py) and the wall-clock ratio is purely
    the orchestration difference."""
    import jax
    k = _flag("loop-baseline")
    k = min(len(labels), 3) if k is None else min(len(labels), int(k))
    if k <= 0:
        return None
    t0 = time.time()
    for spec in scen_specs[:k]:
        if tcfg is None:
            res = simulate_grid(algo, p.cluster, p.rates, list(loads),
                                n_seeds, p.cfg, scenario=spec, pad=pad,
                                a_max=a_max)
        else:
            res, _ = simulate_grid_with_telemetry(
                algo, p.cluster, p.rates, list(loads), n_seeds, p.cfg,
                scenario=spec, pad=pad, a_max=a_max, telemetry=tcfg)
        jax.block_until_ready(res.mean_completion_norm)
    wall = time.time() - t0
    n = k * len(loads) * n_seeds
    return {"policy": algo, "n_scenarios": k, "scenarios": labels[:k],
            "cells": n, "wall_s": wall, "cells_per_s": n / max(wall, 1e-9),
            "cells_per_s_one_program": None}


def _grid_cell_events(p, algo, labels, loads, n_seeds, tele, tcfg, sink,
                      wall):
    """Per-cell JSONL events: slice each (scenario, load) cell out of the
    stacked telemetry (cell_view — seeds aggregate, cells never mix) and
    emit a run manifest + windows + histograms per cell."""
    for s, lbl in enumerate(labels):
        for j, l in enumerate(loads):
            cell = cell_view(tele, (s, slice(None), j))
            sink.extend(to_events(
                cell, tcfg, p.cfg.T, p.cfg.warmup,
                run_manifest(suite="grid", scenario=lbl, algo=algo,
                             load=float(l), seeds=n_seeds, T=p.cfg.T,
                             warmup=p.cfg.warmup, wall_s=wall,
                             trace_count=trace_count())))


def _print_grid_table(out: dict):
    """Mean +/- 95% CI per (scenario, load) cell, one block per policy."""
    loads = out["loads"]
    for algo in out["policies"]:
        print(f"\n== grid sweep: {algo} ({out['preset']} preset, "
              f"{out['seeds']} seeds) ==")
        print(f"{'scenario':22s} " + " ".join(
            f"{'rho=' + str(l):>17s}" for l in loads))
        for lbl in out["scenarios"]:
            row = out["cells"][algo][lbl]
            parts = []
            for l in loads:
                c = row[str(l)]
                ci = c["ci"]
                ci_s = f"{ci:6.2f}" if np.isfinite(ci) else "   n/a"
                d = c["drift"]
                mark = "!" if d != d else ("*" if d > 1.5 else " ")
                parts.append(f"{c['mean']:8.2f} ±{ci_s}{mark}")
            print(f"{lbl:22s} " + " ".join(parts))
    print("(± = 95% CI over seed replications; * = unstable cell: drift "
          "> 1.5, expected near capacity for outage scenarios; ! = drift "
          "unmeasurable, treat as NOT converged)")


if __name__ == "__main__":
    if "--grid" in sys.argv[1:]:
        grid_main()
    else:
        main()
