"""Scenario sweep — the paper's algorithms under the scenario registry.

For every registered scenario (heterogeneous fleets, bursty / diurnal /
flash traffic, Zipf placement — repro.scenarios) this suite runs
Balanced-Pandas, Balanced-Pandas-Pod and JSQ-MaxWeight-Pod at the preset's
fixed load and reports mean task completion time, plus BP-Pod's
*sensitivity to d*: the paper's claim is that d barely matters (d=8 probes
recover the O(M) policy); scenarios show where that stops being true.

sensitivity_d = (mean_T[d=3] - mean_T[d=16]) / mean_T[d=16]
  ~0   -> the scenario is insensitive to the probe budget (paper regime)
  >>0  -> small candidate sets hurt; locality/heterogeneity makes extra
         probes valuable.

One-compile sweep: every scenario is realized against the registry-wide
canonical pad (scenarios.canonical_pad) with one shared a_max, so the jit'd
simulator step compiles once per (algo, pod) and the other scenarios ride
the cache — the per-scenario recompile used to dominate smoke wall-clock.
``--scenarios=name1,name2`` restricts the sweep (CI runs one natively-padded
and one natively-max-shaped scenario).  A ``+`` inside a name composes
registry scenarios on the fly (``--scenarios=slow_rack+flash_crowd`` runs
scenarios.compose of the two): the registry pad reserves pairwise window
headroom, so ad-hoc pairs stay on the registry's compiled signature (the
shared a_max is widened over the selection when a composition's traffic
peak exceeds the registry's).

``--metrics-out=FILE`` turns on the in-jit telemetry collectors
(repro.telemetry; one shared TelemetryConfig keeps the one-compile
property) and writes the full JSONL event stream — per-cell run manifest,
per-window rows, histograms, sojourn percentiles — to FILE.  Cells then
also report windowed drift (telemetry-ring upgrade of the half2/half1
ratio), sojourn p50/p95/p99, and pod probe quality (mean rank / routing
regret vs the O(M) oracle — the observable behind the paper's
d-sensitivity claim).
"""
import sys
import time

import numpy as np

from common import Preset, preset_from_argv, save_artifact

from repro.core import (PodSpec, simulate_grid, simulate_grid_with_telemetry,
                        trace_count)
from repro.scenarios import SCENARIOS, canonical_a_max, canonical_pad, compose
from repro.telemetry import (TelemetryConfig, format_clip_warning,
                             probe_summary, run_manifest,
                             sojourn_percentiles, to_events, windowed_drift,
                             write_jsonl)

ALGOS = ("balanced_pandas", "balanced_pandas_pod", "jsq_maxweight_pod")

# d-sensitivity probe budgets for BP-Pod: (rack, remote) splits keeping the
# paper's 1:3 flavor; d = 3, 8 (paper), 16.
D_SWEEP = (PodSpec(1, 2), PodSpec(2, 6), PodSpec(4, 12))


def _metrics_out_path():
    for a in sys.argv[1:]:
        if a.startswith("--metrics-out="):
            return a.split("=", 1)[1]
    return None


def _mean_T(preset: Preset, algo: str, scenario, pod=None,
            pad=None, a_max=None, tcfg=None, sink=None, label=None) -> dict:
    """scenario: a registered name or a Scenario (ad-hoc composition).

    With ``tcfg`` the run collects telemetry: the returned row gains
    drift_windowed / sojourn / probe fields and the cell's JSONL events are
    appended to ``sink`` (a list)."""
    t0 = time.time()
    if tcfg is None:
        res = simulate_grid(algo, preset.cluster, preset.rates,
                            [preset.fixed_load], preset.n_seeds, preset.cfg,
                            pod=pod, scenario=scenario, pad=pad, a_max=a_max)
        tele = None
    else:
        res, tele = simulate_grid_with_telemetry(
            algo, preset.cluster, preset.rates, [preset.fixed_load],
            preset.n_seeds, preset.cfg, pod=pod, scenario=scenario, pad=pad,
            a_max=a_max, telemetry=tcfg)
    t = np.asarray(res.mean_completion_norm)       # [seeds, 1]
    row = {
        "mean": float(np.nanmean(t)),
        "sem": float(np.nanstd(t) / max(np.sqrt(t.shape[0]), 1)),
        "drift": float(np.asarray(res.drift).mean()),
        "local_frac": float(np.asarray(res.locality_fractions)[..., 0].mean()),
        "clip_fraction": float(np.asarray(res.clip_fraction).mean()),
    }
    if tele is not None:
        cfg = preset.cfg
        row["drift_windowed"] = windowed_drift(tele, tcfg, cfg.T, cfg.warmup)
        row["sojourn"] = sojourn_percentiles(tele, tcfg)
        if "note" in row["sojourn"]:
            print(f"[scenarios] NOTE {label}/{algo}: "
                  f"{row['sojourn']['note']}")
        row["probe"] = probe_summary(tele)
        if sink is not None:
            sink.extend(to_events(tele, tcfg, cfg.T, cfg.warmup, run_manifest(
                suite="scenarios", scenario=label, algo=algo,
                d=(pod.d if pod is not None else None),
                load=preset.fixed_load, seeds=preset.n_seeds, T=cfg.T,
                warmup=cfg.warmup, wall_s=time.time() - t0,
                trace_count=trace_count())))
    return row


def _selected_scenarios() -> dict:
    only = [a.split("=", 1)[1] for a in sys.argv[1:]
            if a.startswith("--scenarios=")]
    if not only:
        return dict(SCENARIOS)
    wanted = [n for o in only for n in o.split(",") if n]
    parts = {p for n in wanted for p in (n.split("+") if "+" in n else (n,))}
    unknown = parts - set(SCENARIOS)
    if unknown:
        raise SystemExit(f"--scenarios: unknown {sorted(unknown)}; "
                         f"registered: {sorted(SCENARIOS)}")
    # a `+` composes registry scenarios ad hoc (scenarios.compose)
    return {n: (compose(*n.split("+")) if "+" in n else SCENARIOS[n])
            for n in wanted}


def main(preset=None):
    p = preset or preset_from_argv()
    selected = _selected_scenarios()
    # canonical padding over the FULL registry (not just the selection):
    # any filtered run shares the same compiled signature as the full sweep
    # (pairwise + compositions ride the registry pad's compose headroom);
    # the shared a_max widens over ad-hoc compositions whose traffic peak
    # exceeds the registry's.
    pad = canonical_pad(p.cluster)
    extra = [s for n, s in selected.items() if n not in SCENARIOS]
    # a 3+-way ad-hoc composition can union more windows than the pairwise
    # headroom reserves; widen only then (the run leaves the registry's
    # shared signature, but still compiles once for its own selection)
    need = max((len(s.fleet.windows) for s in extra), default=0)
    if need > pad.n_windows:
        pad = pad._replace(n_windows=need)
    a_max = canonical_a_max(p.cluster, p.rates, p.cfg, p.fixed_load,
                            scenarios=list(SCENARIOS.values()) + extra)
    metrics_out = _metrics_out_path()
    tcfg = TelemetryConfig() if metrics_out else None
    sink = [] if metrics_out else None
    rows = {}
    for name, scen in selected.items():
        t0 = time.time()
        label = name if isinstance(name, str) else str(name)
        row = {"description": scen.description, "algos": {}}
        d_means = {pod.d: _mean_T(p, "balanced_pandas_pod", scen, pod=pod,
                                  pad=pad, a_max=a_max, tcfg=tcfg,
                                  sink=sink, label=label)
                   for pod in D_SWEEP}
        for algo in ALGOS:
            # the d=8 sweep cell IS BP-Pod at its default PodSpec(2, 6)
            # with the same seeds — reuse instead of re-simulating
            row["algos"][algo] = (d_means[8] if algo == "balanced_pandas_pod"
                                  else _mean_T(p, algo, scen, pad=pad,
                                               a_max=a_max, tcfg=tcfg,
                                               sink=sink, label=label))
        d_small, d_large = min(d_means), max(d_means)
        row["d_sweep"] = {str(d): m for d, m in d_means.items()}
        row["sensitivity_d"] = (
            (d_means[d_small]["mean"] - d_means[d_large]["mean"])
            / max(d_means[d_large]["mean"], 1e-9))
        row["wall_s"] = time.time() - t0
        rows[name] = row

        bp = row["algos"]["balanced_pandas"]["mean"]
        pod_t = row["algos"]["balanced_pandas_pod"]["mean"]
        print(f"[scenarios] {name:16s} BP {bp:8.2f}  BP-Pod {pod_t:8.2f} "
              f"({(pod_t - bp) / max(bp, 1e-9):+.1%})  "
              f"JSQ-MW-Pod {row['algos']['jsq_maxweight_pod']['mean']:8.2f}  "
              f"d-sens {row['sensitivity_d']:+.1%}  "
              f"[{row['wall_s']:.1f}s]")
        if tcfg is not None:
            regret = {d: m["probe"]["mean_regret"]
                      for d, m in d_means.items()}
            print("            probe regret (BP-Pod): " + "  ".join(
                f"d={d}: {r:.4f}" if r is not None else f"d={d}: n/a"
                for d, r in sorted(regret.items())))

    out = {"figure": "scenarios", "preset": p.name, "load": p.fixed_load,
           "algos": list(ALGOS), "d_values": [pod.d for pod in D_SWEEP],
           "scenarios": rows}
    save_artifact("scenarios", out)
    _print_table(out)
    # loud clip surfacing: silent arrival truncation biases measured loads
    warn = format_clip_warning(
        [(f"{n}/{a}", r.get("clip_fraction", 0.0))
         for n, row in rows.items() for a, r in row["algos"].items()])
    if warn:
        print(warn)
    if metrics_out:
        write_jsonl(metrics_out, sink, append=False)
        print(f"[scenarios] wrote {len(sink)} telemetry events "
              f"-> {metrics_out}")
    return out


def _print_table(out: dict):
    print(f"\n== scenario sweep ({out['preset']} preset, "
          f"load {out['load']}) ==")
    print(f"{'scenario':16s} {'BP':>9s} {'BP-Pod':>9s} {'JSQ-MW-Pod':>11s} "
          f"{'d-sens':>8s}  {'BP-Pod local%':>13s}")
    for name, row in out["scenarios"].items():
        a = row["algos"]
        def cell(r):
            # prefer the windowed (telemetry-ring) drift when collected
            d = r.get("drift_windowed")
            d = r["drift"] if d is None or d != d else d
            return f"{r['mean']:8.2f}{'*' if d > 1.5 else ' '}"
        print(f"{name:16s} {cell(a['balanced_pandas'])} "
              f"{cell(a['balanced_pandas_pod'])} "
              f"{cell(a['jsq_maxweight_pod']):>11s} "
              f"{row['sensitivity_d']:+7.1%}  "
              f"{a['balanced_pandas_pod']['local_frac']:12.1%}")
    print("(* = unstable: tasks-in-system still growing at end of run; "
          "expected for outage/flash transients at high load, and for "
          "zipf scenarios near capacity — the load calibration is "
          "placement-oblivious, see repro.scenarios docstring)")


if __name__ == "__main__":
    main()
