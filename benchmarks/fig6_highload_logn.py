"""Fig 6 — high-load zoom (log-normal service)."""
from common import ascii_plot, preset_from_argv, print_table, run_figure


def main(preset=None):
    """Reproduce Fig 6 via the shared run_figure harness."""
    p = preset or preset_from_argv()
    out = run_figure(p, p.high_loads, "lognormal", "fig6_highload_logn")
    print_table(out)
    print(ascii_plot(out))
    return out


if __name__ == "__main__":
    main()
