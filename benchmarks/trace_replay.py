"""Trace-replay throughput benchmark -> BENCH_router.json.

Replays the canonical production-day trace through
``repro.trace.replay.ReplayEngine`` (double-buffered host->device arrival
chunks over the fused route_commit megakernel) and compares sustained
routed-tasks/sec against the per-slot ``benchmarks/scenarios.py`` path:
``simulate_grid`` on the same trace-lowered scenario, same cluster / cfg /
load, timed warm.  The trace is sized to load 0.45 of the preset's
placement-free capacity (the replay acceptance operating point).

The datapoint is appended to ``BENCH_router.json`` under its own preset
name (``trace-replay-<preset>``), so scripts/check_router_bench.py gates
replay-vs-replay across commits — the first run of a new preset has
nothing to gate against and passes.

Usage: PYTHONPATH=src python benchmarks/trace_replay.py [--preset=smoke]
                                                        [--require=3.0]
``--require=R`` exits nonzero unless replay sustains at least R x the
per-slot routed-tasks/sec (CI pins the acceptance ratio).
"""
import sys
import time

import numpy as np

from common import preset_from_argv
from router_bench import BENCH_PATH, _append_datapoint

from repro.core import simulate_grid
from repro.trace import production_day, scenario_from_trace
from repro.trace.replay import ReplayEngine

LOAD = 0.45


def _per_slot_tasks_per_s(preset, scn, load) -> dict:
    """Warm routed-tasks/sec of the scenarios.py path (simulate_grid on the
    trace-lowered scenario, the preset's own route_mode)."""
    args = ("balanced_pandas_pod", preset.cluster, preset.rates, [load],
            1, preset.cfg)
    res = simulate_grid(*args, scenario=scn)            # compile + warm
    np.asarray(res.mean_tasks_in_system)                # block
    t0 = time.perf_counter()
    res = simulate_grid(*args, scenario=scn)
    routed = float(np.asarray(res.route_decisions).sum())
    np.asarray(res.mean_tasks_in_system)
    wall = time.perf_counter() - t0
    return {"wall_s": wall,
            "route_mode": preset.cfg.route_mode,
            "slots_per_s": preset.cfg.T / max(wall, 1e-9),
            "tasks_per_s": routed / max(wall, 1e-9)}


def main(preset=None):
    """Replay the production-day trace; append the throughput datapoint."""
    p = preset or preset_from_argv()
    lam_cap = p.cluster.M * p.rates.alpha    # placement-free capacity edge
    n_tasks = int(round(LOAD * lam_cap * p.cfg.T))
    log = production_day(n_tasks=n_tasks)

    eng = ReplayEngine(log, p.cluster, p.rates, cfg=p.cfg)
    cold = eng.run(seed=0)                   # pays the one compile
    res = eng.run(seed=0)                    # timed warm run, zero compiles
    replay = {"wall_s": res.wall_s,
              "slots_per_s": p.cfg.T / max(res.wall_s, 1e-9),
              "tasks_per_s": res.tasks_per_s}
    print(f"[trace_replay] replay   {res.tasks_per_s:12.0f} tasks/s "
          f"({res.routed_tasks} tasks, wall {res.wall_s:.3f}s, "
          f"trace_count cold {cold.trace_count} / warm {res.trace_count})")
    if (cold.trace_count, res.trace_count) != (1, 0):
        raise SystemExit(
            f"[trace_replay] FAIL: expected one compile for the whole "
            f"replay (cold 1 / warm 0), saw cold {cold.trace_count} / "
            f"warm {res.trace_count}")

    scn = scenario_from_trace(log, seed=0)
    base = _per_slot_tasks_per_s(p, scn, eng.load)
    ratio = replay["tasks_per_s"] / max(base["tasks_per_s"], 1e-9)
    print(f"[trace_replay] per-slot {base['tasks_per_s']:12.0f} tasks/s "
          f"({base['route_mode']} route_mode, wall {base['wall_s']:.3f}s)")
    print(f"[trace_replay] replay sustains {ratio:.1f}x the per-slot path")

    point = {
        "date": time.strftime("%Y-%m-%d"),
        "preset": f"trace-replay-{p.name}",
        "M": p.cluster.M, "K": p.cluster.K,
        "T": p.cfg.T, "load": LOAD, "n_tasks": n_tasks,
        "trace": log.name,
        "trace_count": cold.trace_count,       # == 1: one compile per replay
        "trace_count_warm": res.trace_count,   # == 0: warm runs never compile
        "speedup_vs_per_slot": ratio,
        "throughput": {"trace_replay": replay,
                       "per_slot_baseline": base},
    }
    _append_datapoint(point)
    print(f"[trace_replay] appended datapoint -> {BENCH_PATH}")

    require = [float(a.split("=", 1)[1]) for a in sys.argv[1:]
               if a.startswith("--require=")]
    if require and ratio < require[0]:
        raise SystemExit(
            f"[trace_replay] FAIL: replay sustained only {ratio:.2f}x the "
            f"per-slot path (required {require[0]:.2f}x)")
    return point


if __name__ == "__main__":
    main()
