"""Fig 2 — mean completion vs load (exponential/geometric service)."""
from common import ascii_plot, preset_from_argv, print_table, run_figure


def main(preset=None):
    """Reproduce Fig 2 via the shared run_figure harness."""
    p = preset or preset_from_argv()
    out = run_figure(p, p.loads, "geometric", "fig2_exponential")
    print_table(out)
    print(ascii_plot(out))
    return out


if __name__ == "__main__":
    main()
