"""Fig 3 — high-load zoom (exponential/geometric service)."""
from common import ascii_plot, preset_from_argv, print_table, run_figure


def main(preset=None):
    """Reproduce Fig 3 via the shared run_figure harness."""
    p = preset or preset_from_argv()
    out = run_figure(p, p.high_loads, "geometric", "fig3_highload_exp")
    print_table(out)
    print(ascii_plot(out))
    return out


if __name__ == "__main__":
    main()
