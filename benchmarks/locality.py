"""Mechanism behind the paper's headline claim: Balanced-Pandas-Pod routes a
larger fraction of tasks to local/rack-local service than full
Balanced-Pandas at the same load (§V discussion) — restricted sampling makes
it harder for a marginally-less-loaded remote server to win the argmin."""
import dataclasses

import numpy as np

from common import ALGO_LABELS, preset_from_argv, save_artifact
from repro.core import simulate_grid


def main(preset=None):
    """Local/rack/remote service-fraction table per algorithm x load."""
    from common import QUICK
    p = preset or preset_from_argv()
    loads = p.loads
    out = {"loads": list(loads), "algos": {}}
    for algo in ("balanced_pandas", "balanced_pandas_pod"):
        res = simulate_grid(algo, p.cluster, p.rates, list(loads),
                            p.n_seeds, p.cfg)
        loc = np.asarray(res.locality_fractions).mean(axis=0)  # [loads, 3]
        out["algos"][algo] = loc.tolist()
    save_artifact("locality", out)
    print("\n== Service locality fractions (local/rack/remote) ==")
    for algo, loc in out["algos"].items():
        print(f"-- {ALGO_LABELS[algo]}")
        for l, (a, b, c) in zip(loads, loc):
            print(f"   rho={l:<5} local={a:.3f} rack={b:.3f} remote={c:.3f}")
    return out


if __name__ == "__main__":
    main()
