"""Trace replay in 60 seconds: the canonical production-day trace, written
to disk, read back, and replayed through the fused-router front-end.

Walks the whole trace subsystem:
  1. synthesize the production-day arrival log (diurnal x two flash crowds
     x two placement-churn episodes, Zipf popularity, lognormal sizes)
  2. round-trip it through the versioned on-disk format (JSONL here)
  3. replay it with ReplayEngine — double-buffered arrival chunks over the
     fused route_commit kernel, one compile for the whole run
  4. lower the same log to a Scenario and cross-check the simulator's
     mean delay against the replay

    PYTHONPATH=src python examples/trace_replay_demo.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import Cluster, Rates, SimConfig, simulate
from repro.trace import (
    ReplayEngine,
    load as load_log,
    production_day,
    scenario_from_trace,
    write_jsonl,
)


def main():
    # 1. the canonical production day (sized to load 0.45 at this cluster/T)
    log = production_day(n_tasks=8_640)

    # 2. round-trip the versioned format
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "production_day.jsonl")
        write_jsonl(log, path)
        log = load_log(path)
    print(f"trace: {log.n_tasks} tasks over horizon {log.horizon:g}, "
          f"{log.n_epochs} placement epochs, schema {log.schema}")

    # 3. replay through the fused router (cold run compiles, warm run rides
    #    the cache — trace_count stays 1 for the whole replay)
    cluster = Cluster(M=24, K=4)
    rates = Rates(alpha=0.05, beta=0.025, gamma=0.01)
    cfg = SimConfig(T=16_000, warmup=4_000)
    # chunks_per_server sizes the per-epoch catalog budget; 12 keeps tail
    # folding mild on the 512-chunk production catalog
    eng = ReplayEngine(log, cluster, rates, cfg=cfg, chunks_per_server=12)
    eng.run(seed=0)                          # compile + warm
    res = eng.run(seed=0)                    # timed
    print(f"replay: {res.tasks_per_s:,.0f} routed tasks/s "
          f"(wall {res.wall_s:.3f}s, load {eng.load:.2f}, "
          f"compiles this run: {res.trace_count})")
    print(f"replay mean completion: "
          f"{float(res.result.mean_completion_norm):.2f} "
          f"x mean local service")

    # 4. the same trace as a Scenario: the simulator draws fresh arrivals
    #    from the lowered intensity / popularity laws (a few seeds per
    #    side — per-seed delay is noisy on a 2 400-task trace; the frozen
    #    multi-seed acceptance config lives in tests/test_trace.py)
    scn = scenario_from_trace(log, chunks_per_server=12, seed=0)
    rep_t = float(np.mean(
        [float(eng.run(seed=s).result.mean_completion_norm)
         for s in range(5)]))
    sim_t = float(np.mean(
        [float(np.asarray(simulate(
            "balanced_pandas_pod", cluster, rates, eng.load,
            jax.random.PRNGKey(s), cfg=cfg,
            scenario=scn).mean_completion_norm)) for s in range(5)]))
    print(f"mean completion, 5 seeds each: replay {rep_t:.2f}, "
          f"simulator on the lowered scenario {sim_t:.2f} "
          f"({abs(rep_t - sim_t) / sim_t:+.1%} on this short demo trace; "
          f"the frozen T=30k acceptance config in tests/test_trace.py "
          f"holds < 5%)")


if __name__ == "__main__":
    main()
