"""Launch one dry-run cell from Python (what scripts/run_dryrun_sweep.sh
loops over): lower + compile an (arch x shape) on the production mesh and
print its roofline inputs.

    PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape] [mesh]
"""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "deepseek_moe_16b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"
    mesh = sys.argv[3] if len(sys.argv) > 3 else "pod"
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # a dry run owns the process: 512 fake devices are set before jax import
    subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape, "--mesh", mesh,
                    "--out", "artifacts/dryrun"], cwd=ROOT, env=env,
                   check=True)


if __name__ == "__main__":
    main()
