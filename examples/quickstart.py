"""Quickstart: the paper in 60 seconds.

Simulates a 100-server / 10-rack cluster and compares the six scheduling
algorithms at moderate load, then shows the power-of-d complexity win.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import ALGORITHMS, Cluster, Rates, SimConfig, simulate

LABEL = {
    "fcfs": "FCFS",
    "jsq_priority": "JSQ-Priority",
    "jsq_maxweight": "JSQ-MaxWeight",
    "jsq_maxweight_pod": "JSQ-MaxWeight-Pod (d'=12)",
    "balanced_pandas": "Balanced-Pandas",
    "balanced_pandas_pod": "Balanced-Pandas-Pod (d=8)",
}


def main():
    cluster = Cluster(M=100, K=10)           # 10 racks x 10 servers
    rates = Rates(alpha=0.04, beta=0.02, gamma=0.008)
    cfg = SimConfig(T=12_000, warmup=3_000)
    load = 0.8

    print(f"cluster: M={cluster.M} servers, {cluster.K} racks; "
          f"service rates local/rack/remote = {rates.alpha}/{rates.beta}/"
          f"{rates.gamma}; load = {load:.0%} of capacity\n")
    print(f"{'algorithm':28s} {'mean completion':>16s} {'local %':>8s} "
          f"{'probes/route':>13s}")
    for algo in ALGORITHMS:
        r = simulate(algo, cluster, rates, load, jax.random.PRNGKey(0), cfg)
        t = float(r.mean_completion_norm)
        loc = float(r.locality_fractions[0])
        probes = int(r.route_candidates_per_decision)
        print(f"{LABEL[algo]:28s} {t:13.2f} x  {loc:7.1%} {probes:>13d}")
    print("\n(mean completion in units of mean local service time; "
          "probes/route = workloads the central scheduler reads per "
          "routing decision — the paper's O(M) vs O(1) axis)")


if __name__ == "__main__":
    main()
