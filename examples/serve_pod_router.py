"""End-to-end serving driver (the paper's technique in production position).

A fleet of model replicas (grouped into pods) serves batched generation
requests.  Each request's prefix is cached on 3 replicas ("local"); the
router must trade locality against load.  We run the SAME workload under
three routing policies and compare completion time and scheduler cost:

    pod   — Balanced-Pandas-Pod (paper's proposal): 3 locals + d=8 samples,
            O(1) probes, Pallas pod_route kernel
    full  — Balanced-Pandas: argmin over all M replicas, O(M) probes,
            Pallas weighted_argmin kernel
    rand  — uniform random (locality-blind control)

Token generation is real (jit'd decode_step on a small llama-family model).

    PYTHONPATH=src python examples/serve_pod_router.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get
from repro.models import init_params
from repro.sched import FleetTopology, PodRouter, service_rates
from repro.serve import Request, ServeEngine


def run_policy(policy: str, cfg, params, seed=0):
    fleet = FleetTopology(n_replicas=16, n_pods=4)
    router = PodRouter(fleet, service_rates(), policy=
                       "full" if policy == "full" else "pod", seed=seed)
    rng = np.random.default_rng(seed)
    prefix_homes = {i: rng.choice(fleet.n_replicas, size=3, replace=False)
                    for i in range(8)}
    eng = ServeEngine(cfg, params, fleet, router, prefix_homes, max_batch=4,
                      seed=seed)
    if policy == "rand":
        # locality-blind control: random replica, still pays fetch delays
        orig_route = router.route

        def random_route(homes):
            sel = rng.integers(0, fleet.n_replicas, size=len(homes))
            router.stats.decisions += len(homes)
            router.stats.probes += len(homes)
            return sel
        router.route = random_route

    reqs = [Request(rid=i, prefix_id=int(rng.integers(0, 8)),
                    prompt=rng.integers(0, cfg.vocab, size=4),
                    max_new=6, arrival=t * 2)
            for t, i in enumerate(range(48))]
    # submit in arrival waves
    for t in range(0, 96, 2):
        wave = [r for r in reqs if r.arrival == t]
        if wave:
            eng.tick = t
            eng.submit(wave)
            eng.step()
    stats = eng.run(until_done=len(reqs), max_ticks=3000)
    return stats


def main():
    cfg = get("llama3_8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print("serving 48 generation requests on 16 replicas / 4 pods "
          "(real decode on a reduced llama3-family model)\n")
    print(f"{'policy':28s} {'mean compl (ticks)':>18s} {'p95':>6s} "
          f"{'local%':>7s} {'probes/decision':>16s}")
    for policy, label in [("pod", "Balanced-Pandas-Pod (d=8)"),
                          ("full", "Balanced-Pandas O(M)"),
                          ("rand", "random (control)")]:
        s = run_policy(policy, cfg, params)
        comp = np.array(s.completions)
        print(f"{label:28s} {comp.mean():18.1f} {np.percentile(comp, 95):6.0f}"
              f" {s.locality[0]:6.1%} {s.probes_per_decision:16.1f}")
    print("\nPod routing keeps the locality (and completion time) of the "
          "full O(M) scan at ~1/3 of its probe cost here — and the gap "
          "widens with fleet size (see benchmarks/complexity.py).")


if __name__ == "__main__":
    main()
