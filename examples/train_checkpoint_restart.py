"""Fault-tolerant training driver: checkpoints, a simulated node failure,
automatic resume, and straggler-aware data-shard balancing.

    PYTHONPATH=src python examples/train_checkpoint_restart.py
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get
from repro.data import PipelineConfig, SyntheticLM
from repro.optim import AdamWConfig
from repro.sched import ShardBalancer
from repro.train import Trainer, TrainerConfig

CKPT = "/tmp/repro_example_ckpt"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get("llama3_8b", smoke=True)
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=10, total_steps=60)
    pipe = lambda: SyntheticLM(PipelineConfig(vocab=cfg.vocab, seq_len=64,
                                              global_batch=8))

    print("== phase 1: train until an injected node failure at step 25 ==")
    t1 = Trainer(cfg, ocfg, TrainerConfig(total_steps=40, ckpt_every=10,
                                          ckpt_dir=CKPT, log_every=10,
                                          fail_at_step=25, async_ckpt=True),
                 pipe())
    try:
        t1.run()
    except RuntimeError as e:
        print(f"!! {e} — process dies\n")

    print("== phase 2: new process auto-resumes from the last checkpoint ==")
    t2 = Trainer(cfg, ocfg, TrainerConfig(total_steps=40, ckpt_every=10,
                                          ckpt_dir=CKPT, log_every=10),
                 pipe())
    out = t2.run()
    print(f"resumed at step {t2.start_step}, finished at 40; "
          f"final loss {out['losses'][-1]:.3f}\n")

    print("== phase 3: straggler-aware shard balancing (paper's scheduler) ==")
    bal = ShardBalancer(n_workers=16, n_pods=4)
    rng = np.random.default_rng(0)
    # worker 5 degrades to 25% speed after step 50
    for step in range(200):
        for w in range(16):
            slow = (w == 5 and step > 50)
            bal.observe(w, step_time=4.0 if slow else 1.0, expected=1.0)
        bal.assign(rng.choice(16, size=3, replace=False))
        bal.drain(0.3)
    counts = np.zeros(16, int)
    for _ in range(200):
        counts[bal.assign(rng.choice(16, size=3, replace=False))] += 1
        bal.drain(0.3)
    print(f"shards per worker (worker 5 is the straggler): {counts.tolist()}")
    print(f"straggler received {counts[5]} vs healthy mean "
          f"{np.delete(counts, 5).mean():.1f} — O(1) probes/decision: "
          f"{bal.probes / bal.decisions:.1f}")


if __name__ == "__main__":
    main()
