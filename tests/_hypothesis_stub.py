"""Minimal stand-in for `hypothesis` when the real package is absent.

The property tests in test_policies.py only use ``@given`` over
``st.integers`` plus ``settings(max_examples=..., deadline=...)``.  This stub
replays each test over a fixed, deterministic sample of the strategy space —
no shrinking, no database, no adaptive search — which preserves the tests'
value as randomized-input checks while keeping collection working in images
without hypothesis.  conftest.py installs it in ``sys.modules`` only when
``import hypothesis`` fails, so environments with the real library are
unaffected.
"""
from __future__ import annotations

import zlib

import numpy as np


class _IntegersStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def example(self, rng: np.random.Generator) -> int:
        return int(rng.integers(self.lo, self.hi + 1))


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntegersStrategy:
        return _IntegersStrategy(min_value, max_value)


class settings:
    def __init__(self, max_examples: int = 25, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(*strats: _IntegersStrategy):
    def deco(fn):
        max_examples = getattr(fn, "_stub_settings",
                               settings()).max_examples

        def runner():
            # deterministic per-test seed so failures reproduce exactly
            # (zlib.crc32, not hash(): str hashing is salted per process)
            rng = np.random.default_rng(
                zlib.crc32(fn.__name__.encode()))
            for _ in range(max_examples):
                fn(*(s.example(rng) for s in strats))

        # NOT functools.wraps: that copies __wrapped__ and the original
        # signature, making pytest treat strategy params as fixtures.
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco
