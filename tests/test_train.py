"""Training substrate: convergence, microbatch/compression parity,
fault-tolerant resume (bitwise), checkpoint lifecycle."""
import functools
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.data import PipelineConfig, SyntheticLM
from repro.optim import AdamWConfig
from repro.train import (
    Trainer,
    TrainerConfig,
    ef_decode,
    ef_encode,
    init_train_state,
    train_step,
)

CFG = get("llama3_8b", smoke=True)
OCFG = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100)


def _pipe(seed=0, batch=8):
    return SyntheticLM(PipelineConfig(vocab=CFG.vocab, seq_len=64,
                                      global_batch=batch, seed=seed))


def test_loss_decreases():
    state = init_train_state(CFG, OCFG, jax.random.PRNGKey(0))
    step = jax.jit(functools.partial(train_step, cfg=CFG, opt_cfg=OCFG))
    pipe = _pipe()
    losses = []
    for _ in range(30):
        state, m = step(state, pipe.next_batch())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0


def test_microbatch_equals_full_batch_gradients():
    """Accumulated microbatch gradients == one big batch (same data)."""
    state = init_train_state(CFG, OCFG, jax.random.PRNGKey(0))
    batch = _pipe().next_batch()
    s1, m1 = jax.jit(functools.partial(train_step, cfg=CFG, opt_cfg=OCFG,
                                       microbatches=1))(state, batch)
    s2, m2 = jax.jit(functools.partial(train_step, cfg=CFG, opt_cfg=OCFG,
                                       microbatches=4))(state, batch)
    p1 = jax.tree.leaves(s1.params)
    p2 = jax.tree.leaves(s2.params)
    worst = max(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
                for a, b in zip(p1, p2))
    assert worst < 2e-2, worst   # bf16 params; microbatch sums reorder adds


def test_ef_compression_roundtrip_and_parity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    enc = ef_encode(x)
    dec = ef_decode(enc)
    rel = float(jnp.abs(x - dec).max() / jnp.abs(x).max())
    assert rel < 0.02   # int8 block quantization error bound
    # training parity: compressed accumulator still converges
    st = init_train_state(CFG, OCFG, jax.random.PRNGKey(0))
    stepc = jax.jit(functools.partial(train_step, cfg=CFG, opt_cfg=OCFG,
                                      microbatches=2, grad_compress=True))
    pipe = _pipe()
    losses = []
    for _ in range(25):
        st, m = stepc(st, pipe.next_batch())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0


def test_int8_optimizer_moments_converge():
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100,
                       moment_dtype="int8")
    st = init_train_state(CFG, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(functools.partial(train_step, cfg=CFG, opt_cfg=ocfg))
    pipe = _pipe()
    losses = []
    for _ in range(25):
        st, m = step(st, pipe.next_batch())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0


def test_failure_recovery_resume_is_bitwise(tmp_path):
    """Train 20 steps straight vs train-crash@12-resume: identical losses
    (params + optimizer + data cursor all checkpointed)."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    tcfg = TrainerConfig(total_steps=20, ckpt_every=6, ckpt_dir=d1,
                         log_every=100, async_ckpt=False)
    t = Trainer(CFG, OCFG, tcfg, _pipe(), log_fn=lambda s: None)
    ref = t.run()["losses"]

    tcfg2 = TrainerConfig(total_steps=20, ckpt_every=6, ckpt_dir=d2,
                          log_every=100, async_ckpt=False, fail_at_step=13)
    t2 = Trainer(CFG, OCFG, tcfg2, _pipe(), log_fn=lambda s: None)
    with pytest.raises(RuntimeError, match="injected failure"):
        t2.run()
    # "new process": fresh trainer auto-resumes from step 12 checkpoint
    tcfg3 = TrainerConfig(total_steps=20, ckpt_every=6, ckpt_dir=d2,
                          log_every=100, async_ckpt=False)
    t3 = Trainer(CFG, OCFG, tcfg3, _pipe(), log_fn=lambda s: None)
    assert t3.start_step == 12
    out = t3.run()["losses"]
    np.testing.assert_array_equal(np.array(ref[12:]), np.array(out))


def test_crash_mid_save_is_harmless(tmp_path):
    """A half-written checkpoint dir (no manifest) is never picked up."""
    from repro.checkpoint import checkpoint as ckpt
    d = str(tmp_path)
    tree = {"w": jnp.arange(8.0)}
    ckpt.save(d, 5, tree)
    # simulate a crash: garbage tmp dir + a step dir without manifest
    os.makedirs(os.path.join(d, "step_00000009"))
    with open(os.path.join(d, "step_00000009", "data.msgpack.zst"), "wb") as f:
        f.write(b"garbage")
    assert ckpt.find_latest(d) == 5
    step, restored, _ = ckpt.restore_latest(d, tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
