"""Benchmark CLI drift guard: ``benchmarks.run --help`` must exit 0 and
name every registered suite and documented flag — the README quickstart
and CI invocations are written against this surface."""
import os
import re
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run_help():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), env.get("PYTHONPATH", "")])
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--help"],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=300)


def test_help_exits_zero_and_names_every_suite():
    proc = _run_help()
    assert proc.returncode == 0, proc.stderr[-2000:]
    # the registry is the source of truth — import it rather than
    # hard-coding the list here, so adding a suite can't silently skip
    # this guard
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    sys.path.insert(0, os.path.join(REPO, "src"))
    try:
        import run as run_mod
    finally:
        sys.path.pop(0)
        sys.path.pop(0)
    assert len(run_mod.SUITES) >= 10
    for name, _, _ in run_mod.SUITES:
        assert re.search(rf"^\s+{re.escape(name)}\s", proc.stdout,
                         re.MULTILINE), f"--help does not list {name}"
    for flag, _ in run_mod.FLAGS:
        bare = flag.split("=")[0]
        assert bare in proc.stdout, f"--help does not document {bare}"


def test_unknown_suite_mentions_help():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only=nope"],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=300)
    assert proc.returncode != 0
    assert "nope" in proc.stderr


def test_check_docs_passes():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "check_docs.py")],
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout[-3000:]
