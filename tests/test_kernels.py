"""Pallas kernels vs pure-jnp oracles: shape & dtype sweeps, interpret mode.

The heterogeneous-rate battery at the bottom checks the kernels against an
independent *numpy* oracle (not ref.py) over randomized [M, 3] inverse-rate
matrices — log-uniform rates spanning 1e-3..1e3, deliberate exact ties,
f32/bf16 workloads, and zero-rate (+inf inverse-rate) servers/columns —
via the hypothesis replay harness (tests/_hypothesis_stub.py when the real
package is absent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import (pod_route, queue_update, ref, route_commit,
                           weighted_argmin)

SHAPES = [(64, 3, 5), (128, 8, 8), (500, 37, 11), (1000, 130, 19), (129, 9, 16)]
INV = jnp.array([25.0, 50.0, 125.0], jnp.float32)


@pytest.mark.parametrize("M,B,C", SHAPES)
@pytest.mark.parametrize("w_dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_argmin_matches_oracle(M, B, C, w_dtype):
    key = jax.random.PRNGKey(M * 1000 + B)
    ks = jax.random.split(key, 2)
    W = (jax.random.uniform(ks[0], (M,)) * 100).astype(w_dtype)
    cls = jax.random.randint(ks[1], (B, M), 0, 3)
    sel, val = weighted_argmin(W, cls, INV)
    rsel, rval = ref.weighted_argmin_ref(W, cls, INV)
    assert (sel == rsel).all()
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval), rtol=1e-5)


@pytest.mark.parametrize("M,B,C", SHAPES)
def test_pod_route_matches_oracle(M, B, C):
    key = jax.random.PRNGKey(M + B)
    ks = jax.random.split(key, 4)
    W = jax.random.uniform(ks[0], (M,)) * 100
    ci = jax.random.randint(ks[1], (B, C), 0, M)
    cc = jax.random.randint(ks[2], (B, C), 0, 3)
    cv = jax.random.bernoulli(ks[3], 0.85, (B, C))
    cv = cv.at[:, 0].set(True)          # at least one valid candidate
    sel, val = pod_route(W, ci, cc, cv, INV)
    rsel, rval = ref.pod_route_ref(W, ci, cc, cv, INV)
    assert (sel == rsel).all()
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval), rtol=1e-5)


@pytest.mark.parametrize("M,B,C", SHAPES)
def test_queue_update_matches_oracle(M, B, C):
    key = jax.random.PRNGKey(M * 7 + B)
    ks = jax.random.split(key, 4)
    Q = jax.random.randint(ks[0], (M, 3), 0, 50)
    sel = jax.random.randint(ks[1], (B,), 0, M)
    scl = jax.random.randint(ks[2], (B,), 0, 3)
    valid = jax.random.bernoulli(ks[3], 0.8, (B,))
    q2, w2 = queue_update(Q, sel, scl, valid, INV)
    rq2, rw2 = ref.queue_update_ref(Q, sel, scl, valid, INV)
    assert (q2 == rq2).all()
    np.testing.assert_allclose(np.asarray(w2), np.asarray(rw2), rtol=1e-5)


# ---------------------------------------------------------------------------
# Heterogeneous [M, 3] inverse-rate battery vs an independent numpy oracle.
# ---------------------------------------------------------------------------

# Small fixed shape pool so the property replays share compiled kernels
# (fresh shapes would recompile the interpret-mode kernels per example).
HETERO_SHAPES = [(64, 3, 5), (128, 8, 8), (129, 9, 16), (96, 17, 11)]


def _np_weighted_argmin(W32, cls, inv_m):
    """Numpy oracle: argmin_m W[m] * inv_m[m, cls[b, m]]; non-finite
    inverse rates score +inf (masked after the multiply); first-index ties."""
    factor = inv_m[np.arange(cls.shape[1])[None, :], cls]          # [B, M]
    with np.errstate(invalid="ignore"):
        scores = np.where(np.isfinite(factor), W32[None, :] * factor, np.inf)
    return np.argmin(scores, axis=1), np.min(scores, axis=1)


def _np_pod_route(W32, ci, cc, cv, inv_m):
    """Numpy oracle for candidate-list routing; first-slot ties."""
    factor = inv_m[ci, cc]                                         # [B, C]
    with np.errstate(invalid="ignore"):
        scores = np.where(cv & np.isfinite(factor), W32[ci] * factor, np.inf)
    c = np.argmin(scores, axis=1)
    return np.take_along_axis(ci, c[:, None], axis=1)[:, 0], np.min(scores, axis=1)


def _hetero_case(seed: int):
    """Randomized heterogeneous routing instance.

    Rates span 1e-3..1e3 log-uniform; some examples draw W and the rate rows
    from tiny discrete pools so exact score ties are dense (including at the
    min); some examples kill whole servers or a single rate column
    (inverse rate +inf); workloads are f32 or bf16.
    """
    rng = np.random.default_rng(seed)
    M, B, C = HETERO_SHAPES[rng.integers(len(HETERO_SHAPES))]
    inv_m = np.exp(rng.uniform(np.log(1e-3), np.log(1e3),
                               (M, 3))).astype(np.float32)
    if rng.random() < 0.5:           # dense exact ties: few distinct rows
        pool = inv_m[:4]
        inv_m = pool[rng.integers(4, size=M)]
    if rng.random() < 0.6:           # dead servers (outage / drain)
        inv_m[rng.choice(M, size=max(1, M // 8), replace=False)] = np.inf
    if rng.random() < 0.4:           # a zero-rate column slice
        inv_m[rng.random(M) < 0.3, rng.integers(3)] = np.inf
    if rng.random() < 0.5:           # few distinct workloads: ties at the min
        W = rng.choice(np.array([0.0, 1.0, 2.5, 77.0], np.float32), size=M)
    else:
        W = rng.uniform(0, 100, M).astype(np.float32)
    dtype = jnp.bfloat16 if rng.random() < 0.4 else jnp.float32
    W_j = jnp.asarray(W).astype(dtype)
    W32 = np.asarray(W_j.astype(jnp.float32))    # what the kernel computes on
    return rng, M, B, C, inv_m, W_j, W32


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_weighted_argmin_hetero_property(seed):
    rng, M, B, C, inv_m, W_j, W32 = _hetero_case(seed)
    cls = rng.integers(0, 3, (B, M)).astype(np.int32)
    sel, val = weighted_argmin(W_j, jnp.asarray(cls), jnp.asarray(inv_m))
    nsel, nval = _np_weighted_argmin(W32, cls, inv_m)
    np.testing.assert_array_equal(np.asarray(sel), nsel)
    np.testing.assert_allclose(np.asarray(val), nval, rtol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pod_route_hetero_property(seed):
    rng, M, B, C, inv_m, W_j, W32 = _hetero_case(seed)
    ci = rng.integers(0, M, (B, C)).astype(np.int32)
    if rng.random() < 0.5:           # duplicate candidates: exact slot ties
        ci[:, 1::2] = ci[:, 0::2][:, :ci[:, 1::2].shape[1]]
    cc = rng.integers(0, 3, (B, C)).astype(np.int32)
    cv = rng.random((B, C)) < 0.85
    cv[:, 0] = True
    sel, val = pod_route(W_j, jnp.asarray(ci), jnp.asarray(cc),
                         jnp.asarray(cv), jnp.asarray(inv_m))
    nsel, nval = _np_pod_route(W32, ci, cc, cv, inv_m)
    np.testing.assert_array_equal(np.asarray(sel), nsel)
    np.testing.assert_allclose(np.asarray(val), nval, rtol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_queue_update_hetero_property(seed):
    rng, M, B, C, inv_m, W_j, W32 = _hetero_case(seed)
    Q = rng.integers(0, 50, (M, 3)).astype(np.int32)
    sel = rng.integers(0, M, B).astype(np.int32)
    scl = rng.integers(0, 3, B).astype(np.int32)
    valid = rng.random(B) < 0.8
    q2, w2 = queue_update(jnp.asarray(Q), jnp.asarray(sel), jnp.asarray(scl),
                          jnp.asarray(valid), jnp.asarray(inv_m))
    nq = Q.copy()
    np.add.at(nq, (sel[valid], scl[valid]), 1)
    inv_f = np.where(np.isfinite(inv_m), inv_m, 0.0)
    nw = (nq * inv_f).sum(axis=1, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(q2), nq)
    np.testing.assert_allclose(np.asarray(w2), nw, rtol=1e-5)


@pytest.mark.parametrize("M,B,C", SHAPES)
def test_weighted_argmin_hetero_matches_jnp_ref(M, B, C):
    """ref.py (the jnp oracle) and the kernel agree on [M, 3] operands too."""
    rng = np.random.default_rng(M * 31 + B)
    inv_m = rng.uniform(1e-2, 1e2, (M, 3)).astype(np.float32)
    inv_m[:: max(M // 7, 1)] = np.inf
    W = rng.uniform(0, 100, M).astype(np.float32)
    cls = rng.integers(0, 3, (B, M)).astype(np.int32)
    sel, val = weighted_argmin(jnp.asarray(W), jnp.asarray(cls),
                               jnp.asarray(inv_m))
    rsel, rval = ref.weighted_argmin_ref(jnp.asarray(W), jnp.asarray(cls),
                                         jnp.asarray(inv_m))
    np.testing.assert_array_equal(np.asarray(sel), np.asarray(rsel))
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval), rtol=1e-5)


def test_hetero_lowest_index_ties_survive_onehot_formulation():
    """All-equal scores: the one-hot gather/argmin must keep the lowest
    server index (weighted_argmin) / lowest candidate slot (pod_route)."""
    M, B, C = 96, 11, 9
    W = jnp.full((M,), 3.0, jnp.float32)
    inv_m = jnp.broadcast_to(jnp.float32(2.0), (M, 3))
    cls = jnp.zeros((B, M), jnp.int32)
    sel, val = weighted_argmin(W, cls, inv_m)
    assert (np.asarray(sel) == 0).all()
    np.testing.assert_allclose(np.asarray(val), 6.0)

    rng = np.random.default_rng(0)
    ci = jnp.asarray(rng.integers(0, M, (B, C)).astype(np.int32))
    cc = jnp.ones((B, C), jnp.int32)
    cv = jnp.ones((B, C), bool)
    sel, _ = pod_route(W, ci, cc, cv, inv_m)
    np.testing.assert_array_equal(np.asarray(sel), np.asarray(ci)[:, 0])


def test_hetero_zero_rate_never_selected_over_live_candidate():
    """A drained (zero-rate, +inf inverse-rate) server with an EMPTY queue
    must score +inf — not 0 * inf = NaN — so a live candidate always wins."""
    M, B = 64, 8
    rng = np.random.default_rng(1)
    inv_m = np.full((M, 3), 10.0, np.float32)
    dead = rng.choice(M, size=M // 2, replace=False)
    inv_m[dead] = np.inf
    W = np.zeros(M, np.float32)          # every queue empty: the NaN hazard
    cls = rng.integers(0, 3, (B, M)).astype(np.int32)
    sel, val = weighted_argmin(jnp.asarray(W), jnp.asarray(cls),
                               jnp.asarray(inv_m))
    assert not np.isin(np.asarray(sel), dead).any()
    assert np.isfinite(np.asarray(val)).all()


# ---------------------------------------------------------------------------
# Fused route_commit megakernel: in-kernel sequential-commit semantics vs an
# independent numpy oracle (python loop), the class tie-break lane at large
# workload offsets, and the anti-herding burst contract.
# ---------------------------------------------------------------------------


def _np_route_commit(Q, valid, inv_m, cls=None, ci=None, cc=None, cv=None,
                     prio=None):
    """Independent numpy sequential-commit oracle: a python loop over
    arrivals.  Arrival b scores against W0 + dW (dW = f32-accumulated
    commits of arrivals 0..b-1, +finite inv_rate each); exact ties break by
    locality class, then the full variant's optional ``prio`` lane, then
    server index (full) / candidate slot (pod, with invalid slots losing
    every tie); dead (+inf) entries mask to +inf after the multiply and
    commit 0 workload."""
    M = Q.shape[0]
    inv_f = np.where(np.isfinite(inv_m), inv_m, 0.0).astype(np.float32)
    dead = ~np.isfinite(inv_m)
    W0 = (Q.astype(np.float32) * inv_f).sum(-1).astype(np.float32)
    dw = np.zeros(M, np.float32)
    Qn = Q.copy()
    B = valid.shape[0]
    sel = np.zeros(B, np.int32)
    scls = np.zeros(B, np.int32)
    val = np.zeros(B, np.float32)
    m = np.arange(M)
    p = m if prio is None else np.asarray(prio)
    for b in range(B):
        if cls is not None:
            factor = inv_f[m, cls[b]]
            ok = ~dead[m, cls[b]]
            scores = np.full(M, np.inf, np.float32)
            scores[ok] = ((W0 + dw) * factor)[ok]
            rank = np.where(scores == scores.min(),
                            (cls[b] * M + p) * M + m, 2**30)
            rb = rank.min()
            s, c = rb % M, rb // (M * M)
            amt = inv_f[s, cls[b, s]]
        else:
            C = ci.shape[1]
            slot = np.arange(C)
            factor = inv_f[ci[b], cc[b]]
            ok = cv[b] & ~dead[ci[b], cc[b]]
            scores = np.full(C, np.inf, np.float32)
            scores[ok] = ((W0 + dw)[ci[b]] * factor)[ok]
            rank = np.where(scores == scores.min(),
                            cc[b] * C + slot + (~cv[b]) * 4 * C, 2**30)
            sl = rank.min() % C
            s, c = ci[b, sl], cc[b, sl]
            amt = factor[sl]
        sel[b], scls[b], val[b] = s, c, scores.min()
        if valid[b]:
            dw[s] = np.float32(dw[s] + amt)
            Qn[s, c] += 1
    return Qn, W0 + dw, sel, scls, val


def _assert_route_commit_equal(out_k, out_np):
    qk, wk, sk, ck, vk = (np.asarray(x) for x in out_k)
    qn, wn, sn, cn, vn = out_np
    np.testing.assert_array_equal(sk, sn)
    np.testing.assert_array_equal(ck, cn)
    np.testing.assert_array_equal(qk, qn)
    np.testing.assert_allclose(wk, wn, rtol=1e-6)
    np.testing.assert_allclose(vk, vn, rtol=1e-6)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_route_commit_full_hetero_property(seed):
    rng, M, B, C, inv_m, _W_j, _W32 = _hetero_case(seed)
    Q = rng.integers(0, 30, (M, 3)).astype(np.int32)
    cls = rng.integers(0, 3, (B, M)).astype(np.int32)
    valid = rng.random(B) < 0.85
    prio = (rng.permutation(M).astype(np.int32)
            if rng.random() < 0.5 else None)
    out = route_commit(jnp.asarray(Q), jnp.asarray(valid), jnp.asarray(inv_m),
                       cls=jnp.asarray(cls),
                       prio=None if prio is None else jnp.asarray(prio))
    _assert_route_commit_equal(
        out, _np_route_commit(Q, valid, inv_m, cls=cls, prio=prio))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_route_commit_pod_hetero_property(seed):
    rng, M, B, C, inv_m, _W_j, _W32 = _hetero_case(seed)
    Q = rng.integers(0, 30, (M, 3)).astype(np.int32)
    ci = rng.integers(0, M, (B, C)).astype(np.int32)
    if rng.random() < 0.5:           # duplicate candidates: exact slot ties
        ci[:, 1::2] = ci[:, 0::2][:, :ci[:, 1::2].shape[1]]
    cc = rng.integers(0, 3, (B, C)).astype(np.int32)
    cv = rng.random((B, C)) < 0.85
    cv[:, 0] = True
    valid = rng.random(B) < 0.85
    out = route_commit(jnp.asarray(Q), jnp.asarray(valid), jnp.asarray(inv_m),
                       cand_idx=jnp.asarray(ci), cand_cls=jnp.asarray(cc),
                       cand_valid=jnp.asarray(cv))
    _assert_route_commit_equal(
        out, _np_route_commit(Q, valid, inv_m, ci=ci, cc=cc, cv=cv))


@pytest.mark.parametrize("M,B,C", SHAPES)
def test_route_commit_matches_jnp_ref(M, B, C):
    """Both variants agree with ref.route_commit_ref (the jnp oracle the
    simulator's telemetry replay shares) across the full shape pool."""
    rng = np.random.default_rng(M * 13 + B)
    inv_m = rng.uniform(1e-2, 1e2, (M, 3)).astype(np.float32)
    inv_m[:: max(M // 7, 1)] = np.inf
    Q = jnp.asarray(rng.integers(0, 40, (M, 3)), jnp.int32)
    valid = jnp.asarray(rng.random(B) < 0.9)
    inv = jnp.asarray(inv_m)

    cls = jnp.asarray(rng.integers(0, 3, (B, M)), jnp.int32)
    prio = jnp.asarray(rng.permutation(M), jnp.int32)
    out_k = route_commit(Q, valid, inv, cls=cls, prio=prio)
    out_r = ref.route_commit_ref(Q, valid, inv, cls=cls, prio=prio)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    ci = jnp.asarray(rng.integers(0, M, (B, C)), jnp.int32)
    cc = jnp.asarray(rng.integers(0, 3, (B, C)), jnp.int32)
    cv = jnp.asarray(rng.random((B, C)) < 0.85, jnp.int32)
    out_k = route_commit(Q, valid, inv, cand_idx=ci, cand_cls=cc,
                         cand_valid=cv)
    out_r = ref.route_commit_ref(Q, valid, inv, cand_idx=ci, cand_cls=cc,
                                 cand_valid=cv)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("offset", [0, 333])
def test_route_commit_class_tiebreak_survives_large_workload(offset):
    """Regression for the deleted _BP_TIE_EPS lift: with every sub-queue at
    ``offset`` and unit rates, every score ties EXACTLY at W = 3*offset
    (999 at offset=333 — where the old host-side ``W + 1e-6`` lift was
    silently absorbed by f32 addition, ulp(999) ~ 6e-5, so ties fell back
    to lowest server index).  The in-kernel integer rank lane must still
    route every arrival to its LOCAL server, never server 0."""
    M, B = 64, 8
    Q = np.full((M, 3), offset, np.int32)
    inv = jnp.ones(3, jnp.float32)
    rng = np.random.default_rng(5)
    local_at = rng.choice(np.arange(1, M), size=B, replace=False)  # never 0
    cls = np.full((B, M), 2, np.int32)
    cls[np.arange(B), local_at] = 0
    _, _, sel, scls, _ = route_commit(jnp.asarray(Q), jnp.ones(B, bool), inv,
                                      cls=jnp.asarray(cls))
    np.testing.assert_array_equal(np.asarray(sel), local_at)
    assert (np.asarray(scls) == 0).all()

    # pod variant: local candidate deliberately NOT in slot 0
    C = 5
    ci = np.stack([rng.choice(M, size=C, replace=False) for _ in range(B)])
    cc = np.tile(np.array([2, 1, 0, 1, 2], np.int32), (B, 1))
    out = route_commit(jnp.asarray(Q), jnp.ones(B, bool), inv,
                       cand_idx=jnp.asarray(ci), cand_cls=jnp.asarray(cc),
                       cand_valid=jnp.ones((B, C), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out[2]), ci[:, 2])
    assert (np.asarray(out[3]) == 0).all()


def test_route_commit_burst_spreads_one_task_per_server():
    """The anti-herding contract: a burst of B arrivals into an all-empty
    equal-rate fleet lands one task per server (each arrival sees the
    previous commits), where snapshot routing would have piled all B onto
    the single argmin server."""
    M, B = 64, 48
    Q0 = jnp.zeros((M, 3), jnp.int32)
    q, w, sel, _, _ = route_commit(Q0, jnp.ones(B, bool), jnp.ones(3),
                                   cls=jnp.zeros((B, M), jnp.int32))
    assert int(np.asarray(q).max()) == 1
    assert len(np.unique(np.asarray(sel))) == B

    # pod variant with every server a candidate: same spread
    ci = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32)[None, :], (B, M))
    q, _, sel, _, _ = route_commit(Q0, jnp.ones(B, bool), jnp.ones(3),
                                   cand_idx=ci,
                                   cand_cls=jnp.zeros((B, M), jnp.int32),
                                   cand_valid=jnp.ones((B, M), jnp.int32))
    assert int(np.asarray(q).max()) == 1
    assert len(np.unique(np.asarray(sel))) == B


def test_route_commit_wseq_replays_decision_workloads():
    """ref.route_commit_wseq row b == the pre-commit workload arrival b
    scored against (the telemetry probe replay contract): row 0 is W0, and
    re-scoring each arrival against its replayed row reproduces the
    kernel's chosen score."""
    rng = np.random.default_rng(9)
    M, B = 96, 17
    Q = jnp.asarray(rng.integers(0, 20, (M, 3)), jnp.int32)
    inv_m = rng.uniform(0.1, 10.0, (M, 3)).astype(np.float32)
    inv_m[5] = np.inf
    inv = jnp.asarray(inv_m)
    cls = jnp.asarray(rng.integers(0, 3, (B, M)), jnp.int32)
    valid = jnp.asarray(rng.random(B) < 0.8)
    _, W_new, sel, scls, val = route_commit(Q, valid, inv, cls=cls)
    wseq = np.asarray(ref.route_commit_wseq(Q, sel, scls, valid, inv))
    inv_f = np.where(np.isfinite(inv_m), inv_m, 0.0)
    np.testing.assert_allclose(
        wseq[0], (np.asarray(Q) * inv_f).sum(-1), rtol=1e-6)
    clsn, seln = np.asarray(cls), np.asarray(sel)
    replayed = wseq[np.arange(B), seln] * inv_f[
        seln, clsn[np.arange(B), seln]]
    np.testing.assert_allclose(replayed, np.asarray(val), rtol=1e-6)


def test_kernels_compose_as_router_pipeline():
    """classes -> pod_route -> queue_update: one routing tick end-to-end."""
    from repro.core import Cluster, PodSpec, locality_class, pod_candidates, sample_locals
    c = Cluster(M=128, K=8)
    key = jax.random.PRNGKey(0)
    locals_ = sample_locals(key, c, 32)
    cls = locality_class(c, locals_)
    ci, cc, cv = pod_candidates(key, c, locals_, cls, PodSpec(2, 6))
    Q = jnp.zeros((c.M, 3), jnp.int32)
    W = jnp.zeros((c.M,), jnp.float32)
    for _ in range(3):
        sel, _ = pod_route(W, ci, cc, cv, INV)
        take = (ci == sel[:, None]).argmax(axis=1)
        sel_cls = jnp.take_along_axis(cc, take[:, None], axis=1)[:, 0]
        Q, W = queue_update(Q, sel, sel_cls, jnp.ones((32,), bool), INV)
    assert int(Q.sum()) == 96
    np.testing.assert_allclose(
        np.asarray(W),
        np.asarray((Q.astype(jnp.float32) * INV[None, :]).sum(-1)), rtol=1e-6)
