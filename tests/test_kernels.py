"""Pallas kernels vs pure-jnp oracles: shape & dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import pod_route, queue_update, ref, weighted_argmin

SHAPES = [(64, 3, 5), (128, 8, 8), (500, 37, 11), (1000, 130, 19), (129, 9, 16)]
INV = jnp.array([25.0, 50.0, 125.0], jnp.float32)


@pytest.mark.parametrize("M,B,C", SHAPES)
@pytest.mark.parametrize("w_dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_argmin_matches_oracle(M, B, C, w_dtype):
    key = jax.random.PRNGKey(M * 1000 + B)
    ks = jax.random.split(key, 2)
    W = (jax.random.uniform(ks[0], (M,)) * 100).astype(w_dtype)
    cls = jax.random.randint(ks[1], (B, M), 0, 3)
    sel, val = weighted_argmin(W, cls, INV)
    rsel, rval = ref.weighted_argmin_ref(W, cls, INV)
    assert (sel == rsel).all()
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval), rtol=1e-5)


@pytest.mark.parametrize("M,B,C", SHAPES)
def test_pod_route_matches_oracle(M, B, C):
    key = jax.random.PRNGKey(M + B)
    ks = jax.random.split(key, 4)
    W = jax.random.uniform(ks[0], (M,)) * 100
    ci = jax.random.randint(ks[1], (B, C), 0, M)
    cc = jax.random.randint(ks[2], (B, C), 0, 3)
    cv = jax.random.bernoulli(ks[3], 0.85, (B, C))
    cv = cv.at[:, 0].set(True)          # at least one valid candidate
    sel, val = pod_route(W, ci, cc, cv, INV)
    rsel, rval = ref.pod_route_ref(W, ci, cc, cv, INV)
    assert (sel == rsel).all()
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval), rtol=1e-5)


@pytest.mark.parametrize("M,B,C", SHAPES)
def test_queue_update_matches_oracle(M, B, C):
    key = jax.random.PRNGKey(M * 7 + B)
    ks = jax.random.split(key, 4)
    Q = jax.random.randint(ks[0], (M, 3), 0, 50)
    sel = jax.random.randint(ks[1], (B,), 0, M)
    scl = jax.random.randint(ks[2], (B,), 0, 3)
    valid = jax.random.bernoulli(ks[3], 0.8, (B,))
    q2, w2 = queue_update(Q, sel, scl, valid, INV)
    rq2, rw2 = ref.queue_update_ref(Q, sel, scl, valid, INV)
    assert (q2 == rq2).all()
    np.testing.assert_allclose(np.asarray(w2), np.asarray(rw2), rtol=1e-5)


def test_kernels_compose_as_router_pipeline():
    """classes -> pod_route -> queue_update: one routing tick end-to-end."""
    from repro.core import Cluster, PodSpec, locality_class, pod_candidates, sample_locals
    c = Cluster(M=128, K=8)
    key = jax.random.PRNGKey(0)
    locals_ = sample_locals(key, c, 32)
    cls = locality_class(c, locals_)
    ci, cc, cv = pod_candidates(key, c, locals_, cls, PodSpec(2, 6))
    Q = jnp.zeros((c.M, 3), jnp.int32)
    W = jnp.zeros((c.M,), jnp.float32)
    for _ in range(3):
        sel, _ = pod_route(W, ci, cc, cv, INV)
        take = (ci == sel[:, None]).argmax(axis=1)
        sel_cls = jnp.take_along_axis(cc, take[:, None], axis=1)[:, 0]
        Q, W = queue_update(Q, sel, sel_cls, jnp.ones((32,), bool), INV)
    assert int(Q.sum()) == 96
    np.testing.assert_allclose(
        np.asarray(W),
        np.asarray((Q.astype(jnp.float32) * INV[None, :]).sum(-1)), rtol=1e-6)
