"""Trace subsystem: ingest round-trips, schema validation, streaming
batches, compiler determinism, canonical-pad compatibility, and the
replay-vs-simulator agreement criterion on the production-day trace."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import Cluster, Rates, SimConfig
from repro.core.simulator import simulate_grid
from repro.scenarios import canonical_pad, get_scenario, realize, \
    scenario_names
from repro.trace import (
    ArrivalLog,
    ReplayEngine,
    arrival_rows,
    catalog_plan,
    iter_slot_batches,
    load as load_log,
    production_day,
    read_jsonl,
    read_npz,
    replay_trace_count,
    reset_replay_trace_count,
    scenario_from_trace,
    stream_slot_batches,
    synth_trace,
    validate_log,
    write_jsonl,
    write_npz,
)


def small_log(n=400, seed=3, **kw):
    kw.setdefault("churn_t", (0.5,))
    kw.setdefault("n_tenants", 2)
    kw.setdefault("n_chunks", 64)
    return synth_trace(name="small", n_tasks=n, seed=seed, **kw)


def assert_logs_equal(a: ArrivalLog, b: ArrivalLog):
    assert a.schema == b.schema and a.name == b.name
    assert a.horizon == pytest.approx(b.horizon)
    np.testing.assert_array_equal(a.chunk, b.chunk)
    np.testing.assert_allclose(a.t, b.t, rtol=0, atol=0)
    np.testing.assert_allclose(a.size, b.size, rtol=0, atol=0)
    assert (a.tenant is None) == (b.tenant is None)
    if a.tenant is not None:
        np.testing.assert_array_equal(a.tenant, b.tenant)
    assert a.churn_t == pytest.approx(b.churn_t)


# ---------------------------------------------------------------------------
# ingest: encodings round-trip and agree with each other
# ---------------------------------------------------------------------------


def test_jsonl_npz_roundtrip_equal(tmp_path):
    log = small_log()
    pj, pn = tmp_path / "a.jsonl", tmp_path / "a.npz"
    write_jsonl(log, pj)
    write_npz(log, pn)
    from_jsonl = read_jsonl(pj)
    from_npz = read_npz(pn)
    assert_logs_equal(from_jsonl, log)
    assert_logs_equal(from_npz, log)
    assert_logs_equal(from_jsonl, from_npz)
    # extension-dispatched loader hits the same decoders
    assert_logs_equal(load_log(pj), from_jsonl)
    assert_logs_equal(load_log(pn), from_npz)


def test_loader_rejects_unknown_extension(tmp_path):
    with pytest.raises(ValueError, match="extension"):
        load_log(tmp_path / "a.csv")


def test_validate_log_catches_schema_violations():
    log = small_log()
    assert validate_log(log) == []
    bad = dataclasses.replace(log, t=log.t[::-1].copy())
    assert any("sorted" in e for e in validate_log(bad))
    bad = dataclasses.replace(log, schema="repro.trace/v0")
    assert any("schema" in e for e in validate_log(bad))
    bad = dataclasses.replace(log, size=-log.size)
    assert any("size" in e for e in validate_log(bad))
    bad = dataclasses.replace(log, churn_t=(0.8, 0.2))
    assert any("churn_t" in e for e in validate_log(bad))


def test_streaming_batches_match_in_memory(tmp_path):
    log = small_log()
    p = tmp_path / "s.jsonl"
    write_jsonl(log, p)
    T, B = 64, 20
    mem = list(iter_slot_batches(log, T, B))
    stream = list(stream_slot_batches(p, T, B))
    assert len(mem) == len(stream) == -(-T // B)
    total = 0
    for bm, bs in zip(mem, stream):
        assert bm.slot0 == bs.slot0
        np.testing.assert_array_equal(bm.counts, bs.counts)
        np.testing.assert_array_equal(bm.slot, bs.slot)
        np.testing.assert_array_equal(bm.chunk, bs.chunk)
        np.testing.assert_allclose(bm.size, bs.size, rtol=0)
        total += bm.slot.shape[0]
    assert total == log.n_tasks


# ---------------------------------------------------------------------------
# compiler: deterministic lowering within the canonical signature
# ---------------------------------------------------------------------------


def test_catalog_plan_partitions_mass():
    log = small_log(n=2000, n_chunks=256)
    budget = 48
    plans = catalog_plan(log, budget)
    assert sum(p.mass.shape[0] for p in plans) == budget
    assert sum(float(p.mass.sum()) for p in plans) == log.n_tasks
    rows = arrival_rows(log, budget)
    assert rows.min() >= 0 and rows.max() < budget
    # per-row mass from the task stream matches the plan exactly
    np.testing.assert_allclose(
        np.bincount(rows, minlength=budget),
        np.concatenate([p.mass for p in plans]))


def test_compiler_determinism_bit_identical():
    log = small_log()
    cluster, rates, T = Cluster(M=8, K=2), Rates(), 256
    a = realize(scenario_from_trace(log, seed=5), cluster, rates, T)
    b = realize(scenario_from_trace(log, seed=5), cluster, rates, T)
    sa, sb = a[0], b[0]
    assert a[1] == b[1]
    for la, lb in zip(jax.tree_util.tree_leaves(sa),
                      jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # a different scenario seed moves the replica triples
    c = realize(scenario_from_trace(log, seed=6), cluster, rates, T)[0]
    assert not np.array_equal(np.asarray(c.chunk_locals),
                              np.asarray(sa.chunk_locals))


def test_production_day_is_registered_and_realizes_canonically():
    assert "production_day" in scenario_names()
    assert "adversarial_placement" in scenario_names()
    scn = get_scenario("production_day")
    cluster, rates = Cluster(M=8, K=2), Rates()
    scen, lam_cap = realize(scn, cluster, rates, 128,
                            pad=canonical_pad(cluster))
    assert lam_cap > 0
    assert scen.placement_epoch is not None
    assert scen.epoch_logits is not None
    # three churn epochs appear on the slot grid
    assert set(np.asarray(scen.placement_epoch).tolist()) == {0, 1, 2}


# ---------------------------------------------------------------------------
# replay engine: one compile, and agreement with the simulator
# ---------------------------------------------------------------------------


def test_replay_single_compile_and_throughput_fields():
    log = small_log(n=600)
    eng = ReplayEngine(log, Cluster(M=8, K=2), Rates(),
                       cfg=SimConfig(T=256, warmup=64), chunk_slots=64)
    reset_replay_trace_count()
    r1 = eng.run(seed=0)
    assert replay_trace_count() == 1       # all chunks share one signature
    r2 = eng.run(seed=1)
    assert replay_trace_count() == 1       # second run hits the cache
    assert r1.trace_count == 1      # one compile serves every chunk
    assert r2.trace_count == 0      # warm run: no recompilation at all
    assert r1.routed_tasks == log.n_tasks
    assert r1.tasks_per_s > 0 and r1.wall_s > 0
    assert float(r1.result.mean_completion_norm) > 0
    # full-BP variant shares nothing with the pod cache but also compiles once
    eng2 = ReplayEngine(log, Cluster(M=8, K=2), Rates(),
                        algo="balanced_pandas",
                        cfg=SimConfig(T=256, warmup=64), chunk_slots=64)
    reset_replay_trace_count()
    eng2.run(seed=0)
    assert replay_trace_count() == 1


def test_replay_agrees_with_simulator_on_production_day():
    """The acceptance criterion: mean delay within 5% of the per-slot
    simulator on the production-day trace at load 0.45 (M=24 keeps the
    hot-row utilization ~0.47 so neither side is knife-edge; measured
    gap at this frozen configuration: 1.3%)."""
    cluster, rates = Cluster(M=24, K=4), Rates()
    cfg = SimConfig(T=30_000, warmup=6_000)
    log = production_day(n_tasks=12_960)    # == load 0.45 at T=30k
    eng = ReplayEngine(log, cluster, rates, cfg=cfg, chunks_per_server=12)
    assert eng.load == pytest.approx(0.45, abs=1e-6)
    replay = np.mean([float(eng.run(seed=s).result.mean_completion_norm)
                      for s in range(8)])
    scn = scenario_from_trace(log, chunks_per_server=12, seed=0)
    grid = simulate_grid("balanced_pandas_pod", cluster, rates,
                         [eng.load], n_seeds=16, cfg=cfg, scenario=scn)
    sim = float(np.mean(np.asarray(grid.mean_completion_norm)[:, 0]))
    rel = abs(replay - sim) / sim
    assert rel < 0.05, f"replay {replay:.4f} vs sim {sim:.4f}: rel {rel:.4f}"
