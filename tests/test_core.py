"""Core scheduler tests: paper-model invariants, oracle agreement, claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALGORITHMS,
    Cluster,
    PodSpec,
    Rates,
    SimConfig,
    capacity_arrival_rate,
    locality_class,
    sample_locals,
    simulate,
)
from repro.core.refsim import simulate_bp_ref

CLUSTER = Cluster(M=40, K=4)
RATES = Rates(0.05, 0.025, 0.01)
QUICK = SimConfig(T=6_000, warmup=1_500)


def _run(algo, load, seed=0, cfg=QUICK, cluster=CLUSTER, **kw):
    return simulate(algo, cluster, RATES, load, jax.random.PRNGKey(seed),
                    cfg, **kw)


def test_all_algorithms_run_and_are_stable_at_moderate_load():
    for algo in ALGORITHMS:
        r = _run(algo, 0.5)
        assert np.isfinite(float(r.mean_completion_slots)), algo
        if algo != "fcfs":   # fcfs loses capacity to remote service
            assert float(r.drift) < 1.6, (algo, float(r.drift))
            # throughput tracks arrivals when stable
            assert abs(float(r.throughput) / float(r.arrival_rate_hat) - 1) \
                < 0.1, algo


def test_littles_law_matches_event_accurate_reference():
    """The vectorized simulator's Little's-law completion time agrees with
    the numpy per-task sojourn oracle."""
    ref = simulate_bp_ref(CLUSTER, RATES, 0.7, T=10_000, warmup=2_500, seed=0)
    vals = [float(_run("balanced_pandas", 0.7, seed=s,
                       cfg=SimConfig(T=10_000, warmup=2_500)
                       ).mean_completion_slots) for s in range(3)]
    est = np.mean(vals)
    assert abs(est - ref.mean_completion_slots) / ref.mean_completion_slots \
        < 0.15, (est, ref.mean_completion_slots)


def test_balanced_pandas_enhances_locality_vs_jsq_family():
    """Paper §V discussion: BP(-Pod) serves a (much) larger local fraction."""
    bp = _run("balanced_pandas", 0.6)
    pod = _run("balanced_pandas_pod", 0.6)
    fcfs = _run("fcfs", 0.3)
    assert float(bp.locality_fractions[0]) > 0.7
    assert float(pod.locality_fractions[0]) > 0.7
    assert float(fcfs.locality_fractions[0]) < 0.3


def test_pod_complexity_counters():
    """Paper §IV-C: BP-Pod probes (3+d) workloads per routing decision vs M;
    for M=500, d=8 that is 2.2%."""
    r_full = _run("balanced_pandas", 0.4)
    r_pod = _run("balanced_pandas_pod", 0.4)
    assert float(r_full.route_candidates_per_decision) == CLUSTER.M
    assert float(r_pod.route_candidates_per_decision) == 3 + 8
    big = Cluster(M=500, K=10)
    frac = (3 + 8) / big.M
    assert abs(frac - 0.022) < 1e-3


def test_bp_pod_with_full_candidate_set_equals_bp_distribution():
    """d -> everything makes Pod behave like full BP (same load level)."""
    cfg = SimConfig(T=8_000, warmup=2_000)
    full_pod = PodSpec(d_rack=CLUSTER.M, d_remote=CLUSTER.M)
    a = np.mean([float(_run("balanced_pandas", 0.75, seed=s, cfg=cfg)
                       .mean_completion_slots) for s in range(3)])
    b = np.mean([float(_run("balanced_pandas_pod", 0.75, seed=s, cfg=cfg,
                            pod=full_pod).mean_completion_slots)
                 for s in range(3)])
    assert abs(a - b) / a < 0.15, (a, b)


def test_batched_and_sequential_routing_agree():
    cfg_b = SimConfig(T=8_000, warmup=2_000, route_mode="batched")
    cfg_s = SimConfig(T=8_000, warmup=2_000, route_mode="sequential")
    for algo in ("balanced_pandas_pod", "jsq_maxweight"):
        a = float(_run(algo, 0.7, cfg=cfg_s).mean_completion_slots)
        b = float(_run(algo, 0.7, cfg=cfg_b).mean_completion_slots)
        assert abs(a - b) / a < 0.25, (algo, a, b)


def test_capacity_region_scaling():
    lam = capacity_arrival_rate(CLUSTER, RATES, 0.5)
    assert lam == pytest.approx(0.5 * CLUSTER.M * RATES.alpha)


def test_locality_class_partition():
    key = jax.random.PRNGKey(0)
    locals_ = sample_locals(key, CLUSTER, 64)
    cls = locality_class(CLUSTER, locals_)
    # each task: exactly 3 local servers, rack-locals within local racks
    assert (jnp.sum(cls == 0, axis=1) == 3).all()
    R = CLUSTER.rack_size
    n_rack = jnp.sum(cls == 1, axis=1)
    assert (n_rack <= 3 * (R - 1)).all() and (n_rack >= R - 3).all()
    assert ((cls >= 0) & (cls <= 2)).all()


def test_sample_locals_distinct_and_uniform():
    key = jax.random.PRNGKey(1)
    loc = np.asarray(sample_locals(key, CLUSTER, 4000))
    assert all(len(set(row)) == 3 for row in loc)
    counts = np.bincount(loc.reshape(-1), minlength=CLUSTER.M)
    expect = loc.size / CLUSTER.M
    assert counts.min() > 0.6 * expect and counts.max() < 1.4 * expect


def test_geometric_and_lognormal_service():
    for dist in ("geometric", "lognormal"):
        cfg = SimConfig(T=6_000, warmup=1_500, service_dist=dist)
        r = _run("balanced_pandas_pod", 0.5, cfg=cfg)
        assert np.isfinite(float(r.mean_completion_slots))
        assert float(r.drift) < 1.6
