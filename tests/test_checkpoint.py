"""Checkpoint: roundtrip, integrity, GC, async, elastic reshard (subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


def _tree(key):
    a, b = jax.random.split(key)
    return {"layer": {"w": jax.random.normal(a, (16, 8)),
                      "b": jnp.zeros((8,), jnp.bfloat16)},
            "step": jnp.int32(7),
            "m": jax.random.normal(b, (33,))}


def test_roundtrip_exact(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 3, tree)
    restored, manifest = ckpt.restore(str(tmp_path), 3, tree)
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_integrity_check_detects_corruption(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    path = ckpt.save(str(tmp_path), 1, tree)
    blob = os.path.join(path, ckpt.data_filename(ckpt.DEFAULT_CODEC))
    import msgpack
    payload = msgpack.unpackb(ckpt.decompress(open(blob, "rb").read(),
                                              ckpt.DEFAULT_CODEC), raw=False)
    k = next(iter(payload))
    payload[k] = payload[k][:-1] + bytes([payload[k][-1] ^ 0xFF])
    with open(blob, "wb") as f:
        f.write(ckpt.compress(msgpack.packb(payload, use_bin_type=True)))
    with pytest.raises(IOError, match="integrity"):
        ckpt.restore(str(tmp_path), 1, tree)


def test_zlib_codec_roundtrip_and_manifest(tmp_path):
    """The stdlib fallback codec roundtrips and is recorded in the manifest."""
    tree = _tree(jax.random.PRNGKey(3))
    path = ckpt.save(str(tmp_path), 5, tree, codec="zlib")
    assert os.path.exists(os.path.join(path, "data.msgpack.zlib"))
    restored, manifest = ckpt.restore(str(tmp_path), 5, tree)
    assert manifest["codec"] == "zlib"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_last_n(tmp_path):
    tree = {"x": jnp.zeros(4)}
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.find_all(str(tmp_path)) == [3, 4, 5]


def test_async_save_then_join(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    ckpt.save(str(tmp_path), 9, tree, async_=True)
    ckpt.join_pending()
    assert ckpt.find_latest(str(tmp_path)) == 9


_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import checkpoint as ckpt

tmp = sys.argv[1]
tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}
# save from a 1x4 mesh sharding
mesh_a = jax.make_mesh((1, 4), ("data", "model"))
sh_a = {"w": NamedSharding(mesh_a, P(None, "model")),
        "b": NamedSharding(mesh_a, P("model"))}
placed = jax.tree.map(jax.device_put, tree, sh_a)
ckpt.save(tmp, 1, placed)
# restore onto a DIFFERENT 4x2 mesh (elastic rescale)
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
sh_b = {"w": NamedSharding(mesh_b, P("data", "model")),
        "b": NamedSharding(mesh_b, P(None))}
restored, _ = ckpt.restore(tmp, 1, tree, shardings=sh_b)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
assert restored["w"].sharding == sh_b["w"]
print("ELASTIC_OK")
"""


def test_elastic_reshard_across_meshes(tmp_path):
    """Checkpoint saved on a 1x4 mesh restores onto a 4x2 mesh (different
    device count layout) — the node-failure / rescale path."""
    r = subprocess.run([sys.executable, "-c", _ELASTIC, str(tmp_path)],
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
