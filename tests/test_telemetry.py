"""Telemetry subsystem tests (PR 6): the two hard contracts — zero
dynamics perturbation, zero extra compiles — plus accuracy of the
histogram percentiles against refsim's exact per-task sojourns, window
accounting against the RawSums accumulators, probe-quality semantics, and
the JSONL export schema."""
import json

import jax
import numpy as np
import pytest

from repro.core import (
    Cluster,
    PodSpec,
    Rates,
    SimConfig,
    reset_trace_count,
    simulate,
    simulate_grid_with_telemetry,
    simulate_with_telemetry,
    trace_count,
)
from repro.core.refsim import simulate_bp_ref
from repro.telemetry import (
    TelemetryConfig,
    aggregate,
    format_clip_warning,
    np_hist,
    percentiles,
    probe_summary,
    read_jsonl,
    run_manifest,
    sojourn_percentiles,
    to_events,
    validate_events,
    window_records,
    windowed_drift,
    write_jsonl,
)

CLUSTER = Cluster(M=40, K=4)
RATES = Rates(0.05, 0.025, 0.01)
TCFG = TelemetryConfig()


def _res_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# Contract 1: collectors never perturb the dynamics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["balanced_pandas", "balanced_pandas_pod",
                                  "jsq_maxweight", "jsq_maxweight_pod",
                                  "jsq_priority", "fcfs"])
def test_telemetry_on_is_bit_identical(algo):
    cfg = SimConfig(T=1_500, warmup=400)
    key = jax.random.PRNGKey(11)
    r0 = simulate(algo, CLUSTER, RATES, 0.6, key, cfg)
    r1, tele = simulate_with_telemetry(algo, CLUSTER, RATES, 0.6, key, cfg,
                                       telemetry=TCFG)
    assert _res_equal(r0, r1), algo
    assert float(np.asarray(tele.win)[:, 0].sum()) == cfg.T  # every slot seen


def test_telemetry_bit_identical_batched_mode():
    cfg = SimConfig(T=1_200, warmup=300, route_mode="batched")
    key = jax.random.PRNGKey(5)
    for algo in ("balanced_pandas", "balanced_pandas_pod"):
        r0 = simulate(algo, CLUSTER, RATES, 0.6, key, cfg)
        r1, _ = simulate_with_telemetry(algo, CLUSTER, RATES, 0.6, key, cfg,
                                        telemetry=TCFG)
        assert _res_equal(r0, r1), algo


# ---------------------------------------------------------------------------
# Contract 2: one shared TelemetryConfig keeps the one-compile sweep
# ---------------------------------------------------------------------------


def test_trace_count_stays_one_across_scenario_sweep_with_telemetry():
    from repro.scenarios import canonical_a_max, canonical_pad
    cluster = Cluster(M=16, K=4)
    # distinctive cfg: the trace counter is process-global, so reuse of
    # another test's signature would undercount, and collisions overcount
    cfg = SimConfig(T=509, warmup=101, s_max=16)
    pad = canonical_pad(cluster)
    a_max = canonical_a_max(cluster, RATES, cfg, 0.6)
    reset_trace_count()
    for scen in ("uniform", "slow_rack", "flash_crowd"):
        simulate_grid_with_telemetry(
            "balanced_pandas_pod", cluster, RATES, [0.3, 0.6], 2, cfg,
            scenario=scen, pad=pad, a_max=a_max, telemetry=TCFG)
    assert trace_count() == 1


# ---------------------------------------------------------------------------
# Window accounting: telemetry sums == the RawSums the SimResult came from
# ---------------------------------------------------------------------------


def test_window_totals_match_simresult_when_warmup_aligned():
    # warmup = 16 windows exactly (T=2048, W=64 -> window_len 32), so the
    # measured-slot accumulators and the measured windows cover the same
    # slots and the totals must agree to float32 accumulation error.
    cfg = SimConfig(T=2_048, warmup=512)
    r, tele = simulate_with_telemetry(
        "balanced_pandas_pod", CLUSTER, RATES, 0.6, jax.random.PRNGKey(3),
        cfg, telemetry=TCFG)
    win = np.asarray(tele.win, np.float64)
    wl = TCFG.window_len(cfg.T)
    assert cfg.warmup % wl == 0
    w0 = cfg.warmup // wl
    slots = win[w0:, 0].sum()
    assert slots == cfg.T - cfg.warmup
    mean_N = win[w0:, 1].sum() / slots
    assert np.isclose(mean_N, float(r.mean_tasks_in_system), rtol=1e-4)
    thr = win[w0:, 5].sum() / slots
    assert np.isclose(thr, float(r.throughput), rtol=1e-4)
    util = win[w0:, 6].sum() / (slots * CLUSTER.M)
    assert np.isclose(util, float(r.utilization), rtol=1e-4)
    # drift from the same ring is finite and near 1 at moderate load
    d = windowed_drift(tele, TCFG, cfg.T, cfg.warmup)
    assert np.isfinite(d) and 0.5 < d < 1.6


# ---------------------------------------------------------------------------
# Sojourn histogram vs refsim's exact per-task sojourns
# ---------------------------------------------------------------------------


def test_sojourn_percentiles_match_refsim_within_5pct():
    T, warmup, load = 12_000, 3_000, 0.45
    ref = simulate_bp_ref(CLUSTER, RATES, load, T=T, warmup=warmup, seed=0)
    cfg = SimConfig(T=T, warmup=warmup)
    _, tele = simulate_with_telemetry(
        "balanced_pandas", CLUSTER, RATES, load, jax.random.PRNGKey(0),
        cfg, telemetry=TCFG)
    got = sojourn_percentiles(tele, TCFG, ps=(50, 95))
    assert got["dropped"] == 0.0
    assert got["n"] > 1_000
    exact = np.percentile(ref.sojourns, [50, 95])
    for key, want in zip(("p50", "p95"), exact):
        err = abs(got[key] - want) / want
        assert err < 0.05, (key, got[key], want)


def test_sojourn_histogram_empty_for_fcfs():
    cfg = SimConfig(T=800, warmup=200)
    _, tele = simulate_with_telemetry(
        "fcfs", CLUSTER, RATES, 0.3, jax.random.PRNGKey(2), cfg,
        telemetry=TCFG)
    assert float(np.asarray(tele.sojourn_hist).sum()) == 0.0
    sp = sojourn_percentiles(tele, TCFG)
    assert np.isnan(sp["p50"])


def test_ring_overflow_drops_records_not_tasks():
    # cap=1 forces overflow at any queueing; the dynamics must not change
    # and drops must be counted
    tiny = TelemetryConfig(ring_cap=1)
    cfg = SimConfig(T=1_500, warmup=400)
    key = jax.random.PRNGKey(7)
    r0 = simulate("balanced_pandas_pod", CLUSTER, RATES, 0.8, key, cfg)
    r1, tele = simulate_with_telemetry(
        "balanced_pandas_pod", CLUSTER, RATES, 0.8, key, cfg, telemetry=tiny)
    assert _res_equal(r0, r1)
    assert float(np.asarray(tele.sojourn_dropped)) > 0


# ---------------------------------------------------------------------------
# Probe quality
# ---------------------------------------------------------------------------


def test_full_bp_probe_rank_is_zero():
    # full Balanced-Pandas IS the O(M) oracle: every decision has rank 0
    cfg = SimConfig(T=1_000, warmup=200)
    _, tele = simulate_with_telemetry(
        "balanced_pandas", CLUSTER, RATES, 0.6, jax.random.PRNGKey(1), cfg,
        telemetry=TCFG)
    s = probe_summary(tele)
    assert s["decisions"] > 0
    assert s["mean_rank"] == 0.0
    assert s["mean_regret"] == 0.0


def test_bp_pod_probe_rank_decreases_with_d():
    cfg = SimConfig(T=2_500, warmup=600)
    ranks = {}
    for pod in (PodSpec(1, 2), PodSpec(4, 12)):
        _, tele = simulate_with_telemetry(
            "balanced_pandas_pod", CLUSTER, RATES, 0.6,
            jax.random.PRNGKey(9), cfg, pod=pod, telemetry=TCFG)
        ranks[pod.d] = probe_summary(tele)
    assert ranks[3]["mean_rank"] > ranks[16]["mean_rank"]
    assert ranks[3]["mean_regret"] > ranks[16]["mean_regret"]


def test_jsq_mw_pod_probe_rank_decreases_with_d():
    cfg = SimConfig(T=2_500, warmup=600)
    ranks = {}
    for pod in (PodSpec(1, 2), PodSpec(4, 12)):
        _, tele = simulate_with_telemetry(
            "jsq_maxweight_pod", CLUSTER, RATES, 0.6,
            jax.random.PRNGKey(9), cfg, pod=pod, telemetry=TCFG)
        ranks[pod.d] = probe_summary(tele)
    assert ranks[3]["mean_rank"] > ranks[16]["mean_rank"]


# ---------------------------------------------------------------------------
# Histogram convention + percentile accuracy
# ---------------------------------------------------------------------------


def test_hist_percentiles_within_bin_width():
    rng = np.random.default_rng(0)
    x = rng.lognormal(3.0, 0.8, size=20_000)
    h = np_hist(x)
    got = percentiles(h, (50, 95, 99))
    want = np.percentile(x, [50, 95, 99])
    for g, w in zip(got, want):
        assert abs(g - w) / w < 0.06, (g, w)   # ~bin width at 8 bins/octave


def test_hist_empty_gives_nan():
    assert np.isnan(percentiles(np.zeros(128), (50,))[0])


# ---------------------------------------------------------------------------
# Export: JSONL events, schema validation, clip warning
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_and_schema(tmp_path):
    cfg = SimConfig(T=1_024, warmup=256)
    _, tele = simulate_with_telemetry(
        "balanced_pandas_pod", CLUSTER, RATES, 0.5, jax.random.PRNGKey(4),
        cfg, telemetry=TCFG)
    events = to_events(tele, TCFG, cfg.T, cfg.warmup,
                       manifest=run_manifest(algo="balanced_pandas_pod",
                                             load=0.5, seeds=1))
    assert validate_events(events) == []
    p = tmp_path / "m.jsonl"
    write_jsonl(str(p), events, append=False)
    back = read_jsonl(str(p))
    assert back == json.loads(json.dumps(events))  # numeric-type stable
    # window rows cover every slot once
    rows = [e for e in back if e["event"] == "window"]
    assert sum(r["slots"] for r in rows) == cfg.T
    # tampering is caught
    bad = [dict(e) for e in events]
    del bad[0]["schema"]
    bad.append({"event": "mystery"})
    errs = validate_events(bad)
    assert len(errs) >= 2


def test_grid_telemetry_aggregates_over_batch_axes():
    cfg = SimConfig(T=512, warmup=128)
    _, tele = simulate_grid_with_telemetry(
        "balanced_pandas_pod", CLUSTER, RATES, [0.3, 0.5], 2, cfg,
        telemetry=TCFG)
    assert np.asarray(tele.win).shape[:2] == (2, 2)
    agg = aggregate(tele)
    win = np.asarray(agg.win)
    assert win.ndim == 2
    assert win[:, 0].sum() == 4 * cfg.T           # seeds x loads x slots
    rows = window_records(agg, TCFG, cfg.T)
    assert rows and all(r["slots"] > 0 for r in rows)


def test_clip_warning_formatting():
    assert format_clip_warning([("a", 0.0), ("b", 0.0)]) is None
    w = format_clip_warning([("cell_a", 0.0), ("cell_b", 2e-3)])
    assert "cell_b" in w and "WARNING" in w and "cell_a" not in w
