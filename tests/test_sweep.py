"""One-program mega-sweep (core.simulate_sweep): stacking contract,
single-compile guard across the FULL registry grid, bit-identical
equivalence with the looped path, per-cell telemetry views, and the
shard_map fallback."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import (
    Cluster,
    Rates,
    SimConfig,
    reset_trace_count,
    simulate_grid,
    simulate_sweep,
    sweep_grid,
    trace_count,
)
from repro.scenarios import (
    SCENARIOS,
    canonical_pad,
    scenario_names,
    stack_scenarios,
)
from repro.telemetry import TelemetryConfig, cell_view

CLUSTER = Cluster(M=16, K=4)
RATES = Rates(0.05, 0.025, 0.01)
# distinctive shapes so these tests cannot ride (or pollute) another
# test's jit cache entry — a collision would hide a retrace
CFG = SimConfig(T=112, warmup=32, route_mode="batched", s_max=16)


# ---------------------------------------------------------------------------
# stacking
# ---------------------------------------------------------------------------


def test_stack_scenarios_shapes_and_caps():
    names = ["uniform", "slow_rack", "zipf_hotspot"]
    stacked, caps = stack_scenarios(names, CLUSTER, RATES, CFG.T)
    assert caps.shape == (3,)
    assert np.all(caps > 0)
    for leaf in jax.tree_util.tree_leaves(stacked):
        assert leaf.shape[0] == 3
    # stacked rows == individually realized scenarios (same pad)
    from repro.scenarios import realize
    pad = canonical_pad(CLUSTER)
    single, _ = realize(SCENARIOS["slow_rack"], CLUSTER, RATES, CFG.T,
                        pad=pad)
    for got, want in zip(jax.tree_util.tree_leaves(stacked),
                         jax.tree_util.tree_leaves(single)):
        np.testing.assert_array_equal(np.asarray(got)[1], np.asarray(want))


def test_stack_scenarios_rejects_undersized_pad():
    pad = canonical_pad(CLUSTER)
    small = pad._replace(n_windows=1)   # straggler_wave needs 4
    with pytest.raises(ValueError, match="pad"):
        stack_scenarios(["uniform", "straggler_wave"], CLUSTER, RATES,
                        CFG.T, pad=small)


def test_sweep_grid_axes():
    names, stacked, lam, a_max = sweep_grid(CLUSTER, RATES, CFG,
                                            [0.4, 0.8])
    assert names == list(scenario_names())
    assert lam.shape == (len(names), 2)
    assert a_max >= 1
    # load axis scales the absolute rate per scenario capacity
    np.testing.assert_allclose(np.asarray(lam[:, 1]) / np.asarray(lam[:, 0]),
                               2.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# the tentpole guards: one compile for the whole grid; cells bit-identical
# to the looped path
# ---------------------------------------------------------------------------


def test_full_registry_grid_is_one_program_per_policy():
    """trace_count advances by EXACTLY 1 per policy for the entire
    registry x loads x seeds grid — the mega-sweep's defining property."""
    loads = [0.4, 0.8]
    for algo in ("balanced_pandas_pod", "jsq_maxweight_pod"):
        reset_trace_count()
        names, res, _ = simulate_sweep(algo, CLUSTER, RATES, loads, 2, CFG)
        t = np.asarray(res.mean_completion_norm)
        assert t.shape == (len(SCENARIOS), 2, 2)
        assert np.isfinite(t).all()
        assert trace_count() == 1, \
            f"{algo}: grid retraced {trace_count()}x"


def test_sweep_cells_bit_identical_to_looped_grid():
    """Every cell of the one-program sweep equals the corresponding
    looped simulate_grid cell bit-for-bit.  The shared a_max matters:
    a different arrival-buffer width changes the PRNG draw shapes, so
    the looped baseline must be given the sweep's a_max."""
    names = ["uniform", "hetero_storm"]
    loads = [0.45, 0.85]
    pad = canonical_pad(CLUSTER)
    _, _, _, a_max = sweep_grid(CLUSTER, RATES, CFG, loads,
                                scenarios=names, pad=pad)
    _, res, _ = simulate_sweep("balanced_pandas_pod", CLUSTER, RATES,
                               loads, 2, CFG, scenarios=names, pad=pad,
                               a_max=a_max)
    swept = np.asarray(res.mean_completion_norm)          # [2, 2, 2]
    for s, name in enumerate(names):
        looped = simulate_grid("balanced_pandas_pod", CLUSTER, RATES,
                               loads, 2, CFG, scenario=name, pad=pad,
                               a_max=a_max)
        want = np.asarray(looped.mean_completion_norm)    # [seeds, loads]
        np.testing.assert_array_equal(swept[s], want, err_msg=name)


def test_sweep_telemetry_has_cell_leading_dims():
    tcfg = TelemetryConfig(sojourns=False)
    names = ["uniform", "slow_rack"]
    loads = [0.5]
    _, res, tele = simulate_sweep("balanced_pandas_pod", CLUSTER, RATES,
                                  loads, 2, CFG, scenarios=names,
                                  telemetry=tcfg)
    assert tele is not None
    assert np.asarray(tele.win).shape[:3] == (2, 2, 1)
    cell = cell_view(tele, (1, slice(None), 0))
    # the cell slab keeps the seed axis and drops scenario/load
    assert np.asarray(cell.win).shape[0] == 2
    np.testing.assert_array_equal(np.asarray(cell.win),
                                  np.asarray(tele.win)[1, :, 0])


def test_sweep_rejects_empty_scenarios():
    with pytest.raises(ValueError, match="empty"):
        stack_scenarios([], CLUSTER, RATES, CFG.T)


# ---------------------------------------------------------------------------
# shard_map path (forced multi-device CPU in a subprocess)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import numpy as np
import jax
assert jax.device_count() == 2, jax.devices()
from repro.core import Cluster, Rates, SimConfig, simulate_sweep
cluster, rates = Cluster(M=16, K=4), Rates(0.05, 0.025, 0.01)
cfg = SimConfig(T=112, warmup=32, route_mode="batched", s_max=16)
# 3 scenarios on 2 devices: exercises the pad-and-drop uneven split
names = ["uniform", "slow_rack", "zipf_hotspot"]
_, sharded, _ = simulate_sweep("balanced_pandas_pod", cluster, rates,
                               [0.5], 2, cfg, scenarios=names)
_, single, _ = simulate_sweep("balanced_pandas_pod", cluster, rates,
                              [0.5], 2, cfg, scenarios=names,
                              devices=jax.devices()[:1])
a = np.asarray(sharded.mean_completion_norm)
b = np.asarray(single.mean_completion_norm)
assert a.shape == (3, 2, 1), a.shape
np.testing.assert_array_equal(a, b)
print("SHARD_OK")
"""


def test_shard_map_matches_single_device():
    """With 2 forced host devices, the scenario axis shard_maps across
    them and the result is bit-identical to the single-device vmap —
    including the uneven (3 scenarios on 2 devices) pad-and-drop."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARD_OK" in proc.stdout
