"""Distributed pieces that need a multi-device mesh: run in subprocesses
with 8 fake CPU devices (keeps the main test process on 1 device)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(__file__))


def _run(code: str, timeout=900, env_extra=None):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.update(env_extra or {})
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, cwd=ROOT, env=env)
    return r


_RING = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.train import ring_allreduce_q8

mesh = jax.make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000)) * 2

f = shard_map(lambda s: ring_allreduce_q8(s[0], "pod")[None],
              mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None))
got = f(x)   # every shard: the int8-wire ring sum
want = x.sum(axis=0)
rel = float(jnp.abs(got[0] - want).max() / jnp.abs(want).max())
assert rel < 0.05, rel
# HLO carries int8 collective-permutes (the wire-compression evidence)
txt = jax.jit(f).lower(x).compile().as_text()
assert "s8[" in txt and "collective-permute" in txt
print("RING_OK rel=%.4f" % rel)
"""


def test_ring_allreduce_q8_correct_and_int8_on_wire():
    r = _run(_RING)
    assert "RING_OK" in r.stdout, (r.stdout, r.stderr[-2000:])


_DRY = r"""
import os
os.environ["REPRO_DRYRUN_DEVICES"] = "8"
os.environ["REPRO_TEST_MESH"] = "%s"
import sys; sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell
import tempfile, json
out = tempfile.mkdtemp()
rec = run_cell("%s", "%s", "%s", out)
assert "skipped" not in rec, rec
assert rec["memory"]["argument_size_in_bytes"] > 0
assert rec["collectives"]["total_wire_bytes"] > 0
assert rec["collectives"]["unknown_trip_conditions"] == 0
print("DRYRUN_OK", rec["arch"], rec["shape"], rec["mesh"],
      int(rec["collectives"]["total_wire_bytes"]))
"""


def test_dryrun_small_mesh_train():
    """The dry-run machinery end-to-end on a tiny mesh: lower + compile +
    memory/cost/collective extraction for a full-size arch x shape cell
    would take minutes; the smallest arch keeps it tractable."""
    r = _run(_DRY % ("2x4", "deepseek_moe_16b", "train_4k", "pod"),
             timeout=3000)
    assert "DRYRUN_OK" in r.stdout, (r.stdout[-500:], r.stderr[-3000:])


def test_dryrun_small_mesh_multipod_decode():
    r = _run(_DRY % ("2x2x2", "deepseek_moe_16b", "decode_32k", "multipod"),
             timeout=3000)
    assert "DRYRUN_OK" in r.stdout, (r.stdout[-500:], r.stderr[-3000:])


_PIPE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.train import pipeline_forward
mesh = jax.make_mesh((4,), ("pod",))
L, D, B = 8, 16, 8
W = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.2
b = jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1
x = jax.random.normal(jax.random.PRNGKey(2), (B, D))
layer = lambda p, h: jnp.tanh(h @ p["w"] + p["b"])
ref = x
for i in range(L):
    ref = layer({"w": W[i], "b": b[i]}, ref)
out = pipeline_forward(layer, {"w": W, "b": b}, x, mesh=mesh, n_micro=4)
assert float(jnp.abs(out - ref).max()) < 1e-5
txt = jax.jit(lambda p, xx: pipeline_forward(layer, p, xx, mesh=mesh,
              n_micro=4)).lower({"w": W, "b": b}, x).compile().as_text()
assert "collective-permute(" in txt
print("PIPE_OK")
"""


def test_pipeline_parallel_forward_exact():
    """GPipe-style pipeline over the pod axis == sequential layer scan,
    with the DCN hop visible as a collective-permute."""
    r = _run(_PIPE)
    assert "PIPE_OK" in r.stdout, (r.stdout, r.stderr[-2000:])
