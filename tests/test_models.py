"""Per-arch smoke tests + algorithmic equivalence properties."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get
from repro.models import decode_step, forward, init_cache, init_params, param_pspecs
from repro.optim import AdamWConfig
from repro.train import init_train_state, train_step


def _batch(cfg, key, B=2, S=32):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["img_embeds"] = jax.random.normal(key, (B, cfg.n_img_tokens,
                                                  cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + one train step on CPU,
    asserting shapes and no NaNs (assignment requirement)."""
    cfg = get(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    b = _batch(cfg, key)
    params = init_params(cfg, key)
    h, aux = forward(params, cfg, b)
    S_out = 32 + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    assert h.shape == (2, S_out, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())

    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(cfg, ocfg, key)
    b["labels"] = b["tokens"]
    state2, metrics = jax.jit(functools.partial(
        train_step, cfg=cfg, opt_cfg=ocfg))(state, b)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = get(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 16
    cache = init_cache(cfg, B, S)
    if cfg.family == "encdec":
        cache = cache._replace(
            xk=jax.random.normal(key, cache.xk.shape, cache.xk.dtype) * 0.02,
            xv=jax.random.normal(key, cache.xv.shape, cache.xv.dtype) * 0.02)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    h, cache2 = decode_step(params, cfg, cache, tok, jnp.zeros((B,), jnp.int32))
    assert h.shape == (B, 1, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["llama3_8b", "rwkv6_7b", "zamba2_2_7b",
                                  "deepseek_moe_16b"])
def test_prefill_decode_equivalence(arch):
    """Chunked/flash parallel forward == step-by-step recurrent decode
    (f32; MoE capacity raised so no tokens drop)."""
    cfg = get(arch, smoke=True).replace(remat=False, dtype="float32",
                                        capacity_factor=16.0)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    h_fwd, _ = forward(params, cfg, {"tokens": tokens})
    cache = init_cache(cfg, B, S)
    hs = []
    for t in range(S):
        h, cache = decode_step(params, cfg, cache, tokens[:, t:t + 1],
                               jnp.full((B,), t, jnp.int32))
        hs.append(h[:, 0])
    h_dec = jnp.stack(hs, axis=1)
    err = float(jnp.abs(h_fwd - h_dec).max())
    scale = float(jnp.abs(h_fwd).max())
    assert err / scale < 1e-4, (arch, err, scale)


def test_head_padding_is_exact():
    """Zero-masked head padding (yi-34b / whisper layout fix) must be a
    semantic no-op: padded layout with embedded weights == original."""
    cfg0 = get("yi_34b", smoke=True).replace(dtype="float32", remat=False)
    cfgp = cfg0.replace(head_pad_to=4)
    key = jax.random.PRNGKey(0)
    p0 = init_params(cfg0, key)
    pp = init_params(cfgp, key)
    G, Gp, kv = cfg0.q_groups, cfgp.padded_q_groups, cfg0.n_kv_heads
    wq = np.zeros(np.asarray(pp["layers"]["attn"]["wq"]).shape, np.float32)
    wo = np.zeros(np.asarray(pp["layers"]["attn"]["wo"]).shape, np.float32)
    q0 = np.asarray(p0["layers"]["attn"]["wq"])
    o0 = np.asarray(p0["layers"]["attn"]["wo"])
    for k in range(kv):
        wq[:, :, k * Gp:k * Gp + G, :] = q0[:, :, k * G:(k + 1) * G, :]
        wo[:, k * Gp:k * Gp + G, :, :] = o0[:, k * G:(k + 1) * G, :, :]
    pp["layers"]["attn"]["wq"] = jnp.asarray(wq)
    pp["layers"]["attn"]["wo"] = jnp.asarray(wo)
    for nm in ("wk", "wv"):
        pp["layers"]["attn"][nm] = p0["layers"]["attn"][nm]
    for nm in ("ln1", "ln2"):
        pp["layers"][nm] = p0["layers"][nm]
    pp["layers"]["mlp"] = p0["layers"]["mlp"]
    pp["embed"], pp["final_ln"] = p0["embed"], p0["final_ln"]
    tokens = jax.random.randint(key, (2, 16), 0, cfg0.vocab)
    h0, _ = forward(p0, cfg0, {"tokens": tokens})
    hp, _ = forward(pp, cfgp, {"tokens": tokens})
    assert float(jnp.abs(h0 - hp).max()) < 2e-5


def test_moe_combine_weights_and_aux_losses():
    cfg = get("deepseek_moe_16b", smoke=True).replace(dtype="float32")
    from repro.models.moe import moe_apply, moe_params
    key = jax.random.PRNGKey(3)
    p = moe_params(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.1
    out, aux = moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux["lb_loss"])) and float(aux["lb_loss"]) >= 1.0 - 1e-3
    assert np.isfinite(float(aux["z_loss"]))


def test_param_pspec_structure_matches_params():
    for arch in ARCH_IDS:
        cfg = get(arch, smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        specs = param_pspecs(cfg)
        jax.tree.map(lambda a, b: None, params, specs)   # raises on mismatch


def test_flash_attention_matches_reference():
    from repro.models.layers import flash_attention

    def ref_attn(q, k, v, causal):
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * q.shape[-1] ** -0.5
        if causal:
            mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
            s = jnp.where(mask[None, None], s, -jnp.inf)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)

    key = jax.random.PRNGKey(0)
    for causal in (True, False):
        for (S, qb, kb) in [(64, 16, 32), (96, 32, 16)]:
            ks = jax.random.split(key, 4)
            q, k, v, do = (jax.random.normal(kk, (2, S, 3, 32)) for kk in ks)
            f = lambda *a: (flash_attention(*a, causal=causal, q_block=qb,
                                            kv_block=kb) * do).sum()
            g = lambda *a: (ref_attn(*a, causal) * do).sum()
            out_err = jnp.abs(flash_attention(q, k, v, causal=causal,
                                              q_block=qb, kv_block=kb)
                              - ref_attn(q, k, v, causal)).max()
            assert float(out_err) < 1e-5
            gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
            gr = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
            for a, b in zip(gf, gr):
                assert float(jnp.abs(a - b).max()) < 1e-4
