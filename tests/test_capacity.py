"""Placement-aware capacity edge (scenarios.capacity) + auto-extend warmup.

Covers the fluid-LP edge's contract from every side:

* exactness — LP == the hand-computable edge of a single-hot-triple
  catalog, and == the closed form on a disjoint uniform catalog (the
  regression identity);
* dispatch — uniform scenarios keep the closed form BIT-FOR-BIT, skewed
  registry scenarios get a strictly smaller honest edge, padded == raw;
* ground truth — a brute-force refsim stability bracket at small M
  confirms the true edge lies within 2% of the LP optimum;
* the drift-aware auto-extend warmup loop (telemetry.export): slow-mixing
  runs extend and converge below threshold, fast-mixing runs never extend,
  unmeasurable (NaN) drift is loudly NOT converged;
* the 3+-way compose() pad overflow: a helpful ValueError naming
  ``canonical_pad(..., compose_depth=...)``, and that override working.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Cluster, Rates, SimConfig, simulate, simulate_auto_warmup
from repro.core.refsim import simulate_bp_ref
from repro.scenarios import SCENARIOS, canonical_pad, compose, realize
from repro.scenarios.build import ScenarioData
from repro.scenarios.capacity import (
    capacity_edge,
    chunk_demand,
    fluid_edge,
    speed_segments,
    uniform_edge,
)
from repro.telemetry import (
    TelemetryConfig,
    WarmupPolicy,
    auto_extend_warmup,
    windowed_drift,
)

RATES = Rates(0.05, 0.025, 0.01)


def _scen(M, T, logits, locals_):
    """Minimal ScenarioData with an explicit placement catalog."""
    return ScenarioData(
        lam_shape=jnp.ones(T, jnp.float32),
        base_speed=jnp.ones(M, jnp.float32),
        win_start=jnp.zeros(0, jnp.int32),
        win_end=jnp.zeros(0, jnp.int32),
        win_mult=jnp.ones((0, M, 3), jnp.float32),
        chunk_logits=jnp.asarray(logits, jnp.float32),
        chunk_locals=jnp.asarray(locals_, jnp.int32),
    )


# ---------------------------------------------------------------------------
# LP exactness
# ---------------------------------------------------------------------------


def test_lp_matches_hand_computed_single_triple_edge():
    # every task lands on chunk 0 with replicas {0,1,2} = all of rack 0
    # (M=6, K=2, rack_size=3): at the edge the 3 local servers serve at
    # alpha and the 3 remote servers at gamma -> lam* = 3a + 3g exactly.
    cl = Cluster(M=6, K=2)
    scen = _scen(6, 1000, [0.0], [[0, 1, 2]])
    want = 3 * RATES.alpha + 3 * RATES.gamma
    got = fluid_edge(scen, cl, RATES, 1000)
    assert got == pytest.approx(want, rel=1e-9)


def test_lp_regression_identity_uniform_catalog():
    # a disjoint catalog spreading equal demand over all servers is
    # placement-uniform in effect: the LP must reproduce the closed form
    # alpha * M (every server busy on local work at the edge).
    cl = Cluster(M=12, K=3)
    locals_ = [[3 * i, 3 * i + 1, 3 * i + 2] for i in range(4)]
    scen = _scen(12, 1000, [0.0] * 4, locals_)
    got = fluid_edge(scen, cl, RATES, 1000)
    assert got == pytest.approx(uniform_edge(scen, RATES, 1000), rel=1e-9)
    assert got == pytest.approx(RATES.alpha * 12, rel=1e-9)


def test_lp_segments_and_demand_helpers():
    cl = Cluster(M=6, K=2)
    scen = _scen(6, 100, [0.0, np.log(3.0)], [[0, 1, 2], [3, 4, 5]])
    segs = speed_segments(scen, 100)
    assert len(segs) == 1 and segs[0][0] == 100          # no windows: one seg
    pbar, locals_ = chunk_demand(scen, 100)
    assert pbar == pytest.approx([0.25, 0.75])
    assert locals_.shape == (2, 3)


# ---------------------------------------------------------------------------
# dispatch: uniform bit-for-bit, skewed strictly smaller, padded == raw
# ---------------------------------------------------------------------------

CLUSTER = Cluster(M=24, K=4)


@pytest.mark.parametrize("name", ["uniform", "slow_rack", "straggler_wave",
                                  "network_degraded", "flash_crowd"])
def test_uniform_placement_keeps_closed_form_bit_for_bit(name):
    T = 2000
    scen, cap = realize(SCENARIOS[name], CLUSTER, RATES, T)
    assert cap == uniform_edge(scen, RATES, T)           # exact, not approx
    scen_p, cap_p = realize(SCENARIOS[name], CLUSTER, RATES, T,
                            pad=canonical_pad(CLUSTER))
    assert cap_p == cap


@pytest.mark.parametrize("name", ["zipf_hotspot", "adversarial_placement",
                                  "hetero_storm"])
def test_skewed_placement_edge_strictly_below_closed_form(name):
    T = 2000
    scen, cap = realize(SCENARIOS[name], CLUSTER, RATES, T)
    closed = uniform_edge(scen, RATES, T)
    assert 0 < cap < closed
    # padded realization must agree with the raw one (the LP sees through
    # pad rows: they carry exactly zero popularity)
    _, cap_p = realize(SCENARIOS[name], CLUSTER, RATES, T,
                       pad=canonical_pad(CLUSTER))
    assert cap_p == pytest.approx(cap, rel=1e-9)


def test_capacity_edge_is_memoized():
    T = 2000
    scen, _ = realize(SCENARIOS["zipf_hotspot"], CLUSTER, RATES, T)
    a = capacity_edge(scen, CLUSTER, RATES, T)
    b = capacity_edge(scen, CLUSTER, RATES, T)
    assert a == b                                        # cache hit, same value


# ---------------------------------------------------------------------------
# ground truth: refsim stability bracket at small M
# ---------------------------------------------------------------------------


def _half_ratio(cl, load, T, seed, placement):
    """h2/h1 growth statistic from two deterministic refsim runs of the
    SAME seed (refsim is deterministic per seed): warmup=0 gives the full
    mean, warmup=T/2 the second-half mean; h1 = 2*full - h2."""
    full = simulate_bp_ref(cl, RATES, load, T, warmup=0, seed=seed,
                           placement=placement)
    tail = simulate_bp_ref(cl, RATES, load, T, warmup=T // 2, seed=seed,
                           placement=placement)
    h2 = tail.mean_tasks_in_system
    h1 = 2.0 * full.mean_tasks_in_system - h2
    return h2 / max(h1, 1e-9)


def test_refsim_stability_bracket_agrees_with_lp_within_2pct():
    # brute-force oracle: probe the single-hot-triple system 2% below and
    # 2% above the LP edge.  Below: tasks-in-system levels off (half-ratio
    # ~1).  Above: it grows linearly (half-ratio >> 1).  Both classifying
    # correctly brackets the true edge within 2% of the LP optimum.
    cl = Cluster(M=6, K=2)
    T = 50_000
    scen = _scen(6, T, [0.0], [[0, 1, 2]])
    edge = fluid_edge(scen, cl, RATES, T)
    placement = (np.array([1.0]), np.array([[0, 1, 2]]))
    fleet = RATES.alpha * cl.M                 # refsim load is vs fleet edge
    seeds = (0, 1)
    lo = np.mean([_half_ratio(cl, 0.98 * edge / fleet, T, s, placement)
                  for s in seeds])
    hi = np.mean([_half_ratio(cl, 1.02 * edge / fleet, T, s, placement)
                  for s in seeds])
    assert lo < 1.6, f"0.98x edge looks unstable (ratio {lo:.2f})"
    assert hi > 1.6, f"1.02x edge looks stable (ratio {hi:.2f})"


# ---------------------------------------------------------------------------
# auto-extend warmup (telemetry.export.auto_extend_warmup)
# ---------------------------------------------------------------------------

SMALL = Cluster(M=12, K=3)
TCFG = TelemetryConfig()


def test_auto_extend_fast_mixing_run_never_extends():
    _, _, rep = simulate_auto_warmup(
        "balanced_pandas", SMALL, RATES, 0.6, jax.random.PRNGKey(1),
        cfg=SimConfig(T=6000, warmup=1500), telemetry=TCFG)
    assert rep.extensions == 0
    assert rep.converged
    assert rep.warmup == rep.warmup0 == 1500
    assert rep.drift == rep.drift0 < 1.05


def test_auto_extend_slow_mixing_run_extends_and_converges():
    # high load, no configured warmup: the transient ramp-up contaminates
    # the head windows (drift >= threshold), so the loop must move the
    # boundary and land below 1.05
    _, _, rep = simulate_auto_warmup(
        "balanced_pandas", SMALL, RATES, 0.93, jax.random.PRNGKey(1),
        cfg=SimConfig(T=6000, warmup=0), telemetry=TCFG)
    assert rep.drift0 >= 1.05
    assert rep.extensions >= 1
    assert rep.converged
    assert rep.drift < 1.05
    assert rep.warmup > 0
    # tail stats are re-derived and finite
    assert np.isfinite(rep.mean_N) and np.isfinite(rep.mean_completion)


def test_auto_extend_gives_up_loudly_at_cap():
    _, _, rep = simulate_auto_warmup(
        "balanced_pandas", SMALL, RATES, 0.9, jax.random.PRNGKey(0),
        cfg=SimConfig(T=8000, warmup=0), telemetry=TCFG)
    assert not rep.converged
    assert rep.note and "NOT converged" in rep.note
    assert rep.warmup <= int(0.75 * 8000)
    f = rep.fields()
    assert f["warmup_converged"] is False and "warmup_note" in f


def test_nan_drift_is_never_converged():
    # warmup >= T leaves zero measured windows: windowed_drift is NaN and
    # the auto-extend report must say NOT converged, loudly — satellite 2:
    # NaN is "unmeasured", never "converged"
    from repro.core import simulate_with_telemetry
    _, tele = simulate_with_telemetry(
        "balanced_pandas", SMALL, RATES, 0.5, jax.random.PRNGKey(0),
        cfg=SimConfig(T=500, warmup=200), telemetry=TCFG)
    d = windowed_drift(tele, TCFG, 500, 500)
    assert d != d                                        # NaN
    rep = auto_extend_warmup(tele, TCFG, 500, 500)
    assert not rep.converged
    assert "UNMEASURABLE" in rep.note


def test_simresult_drift_nan_when_unmeasurable():
    # satellite 1: warmup >= T means the half-ratio has no first half; the
    # old 1e-9 guard produced a huge finite number (or 0.0), silently
    # misread by drift-threshold consumers
    r = simulate("balanced_pandas", SMALL, RATES, 0.5, jax.random.PRNGKey(0),
                 cfg=SimConfig(T=100, warmup=100))
    assert np.isnan(float(r.drift))


def test_warmup_policy_knobs_respected():
    _, tele = None, None
    from repro.core import simulate_with_telemetry
    _, tele = simulate_with_telemetry(
        "balanced_pandas", SMALL, RATES, 0.93, jax.random.PRNGKey(1),
        cfg=SimConfig(T=6000, warmup=0), telemetry=TCFG)
    # an impossible threshold forces the loop to the cap
    rep = auto_extend_warmup(tele, TCFG, 6000, 0,
                             policy=WarmupPolicy(threshold=0.0,
                                                 max_warmup_frac=0.5))
    assert not rep.converged
    assert rep.warmup <= 3000


# ---------------------------------------------------------------------------
# 3+-way compose(): pad overflow is explicit and fixable (satellite 3)
# ---------------------------------------------------------------------------


def test_three_way_compose_overflow_names_the_fix():
    tri = compose("straggler_wave", "tor_cascade", "cascade_flash")
    with pytest.raises(ValueError, match="compose_depth"):
        realize(tri, CLUSTER, RATES, 2000, pad=canonical_pad(CLUSTER))


def test_three_way_compose_with_widened_pad_matches_raw():
    tri = compose("straggler_wave", "tor_cascade", "cascade_flash")
    pad3 = canonical_pad(CLUSTER, compose_depth=3)
    scen, cap = realize(tri, CLUSTER, RATES, 2000, pad=pad3)
    _, cap_raw = realize(tri, CLUSTER, RATES, 2000)
    assert cap == pytest.approx(cap_raw, rel=1e-12)
    assert scen.win_start.shape[0] == pad3.n_windows


def test_registry_limits_rejects_bad_depth():
    from repro.scenarios.spec import registry_limits
    with pytest.raises(ValueError, match="compose_depth"):
        registry_limits(compose_depth=0)
