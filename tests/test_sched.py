"""Scheduler integration: PodRouter (kernel-backed), straggler balancer,
balls-and-bins asymptotics, serve engine end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ballsbins import max_load, theory_d
from repro.sched import FleetTopology, PodRouter, ShardBalancer, service_rates


def test_router_sequential_commit_spreads_batch():
    fleet = FleetTopology(n_replicas=32, n_pods=4)
    router = PodRouter(fleet, service_rates(), policy="pod")
    homes = np.array([[0, 1, 2]] * 16)
    sel = router.route(homes)
    # empty cluster: the class tie-break sends the first requests to their
    # (local) home replicas, in slot order
    assert sel[:3].tolist() == [0, 1, 2]
    # ...and in-batch sequential commits spread the rest of the burst: an
    # empty sampled candidate (score 0) beats a just-loaded local, so the
    # batch fans out instead of herding onto one snapshot argmin
    assert np.bincount(sel, minlength=32).max() <= 2, sel
    # flood the homes and route again: spillover must be sampled
    for _ in range(20):
        router.route(homes)
    router.route(homes)
    assert router.stats.decisions == 16 * 22
    assert router.stats.probes == 16 * 22 * (3 + 8)   # O(1): 11 probes


def test_router_full_policy_probes_M():
    fleet = FleetTopology(n_replicas=32, n_pods=4)
    router = PodRouter(fleet, service_rates(), policy="full")
    homes = np.array([[0, 1, 2]] * 8)
    router.route(homes)
    assert router.stats.probes == 8 * 32                # O(M)


def test_router_heterogeneous_rate_matrix_avoids_slow_replicas():
    """Per-replica [M, 3] rates: replicas 0-2 run at 1/8 speed, so their
    workload inflates 8x per queued request and the router spills load to
    the fast locals far sooner.  Probe accounting is unchanged."""
    import jax.numpy as jnp

    from repro.core import rate_matrix

    fleet = FleetTopology(n_replicas=32, n_pods=4)
    rates = service_rates()
    speed = np.ones(32, np.float32)
    speed[:3] = 0.125
    rm = np.asarray(rate_matrix(rates, jnp.asarray(speed)))
    slow = PodRouter(fleet, rates, policy="pod", rate_matrix=rm, seed=1)
    base = PodRouter(fleet, rates, policy="pod", seed=1)
    assert slow.heterogeneous and not base.heterogeneous

    homes = np.array([[0, 1, 2]] * 8)       # all requests home on the slow 3
    n_slow_s = n_slow_b = 0
    for _ in range(30):
        n_slow_s += int(np.isin(slow.route(homes), [0, 1, 2]).sum())
        n_slow_b += int(np.isin(base.route(homes), [0, 1, 2]).sum())
    assert n_slow_s < 0.5 * n_slow_b, (n_slow_s, n_slow_b)
    assert slow.stats.probes == base.stats.probes == 30 * 8 * (3 + 8)

    # full policy with per-replica rates: probes stay O(M)
    full = PodRouter(fleet, rates, policy="full", rate_matrix=rm)
    full.route(homes)
    assert full.stats.probes == 8 * 32


def test_straggler_rebalancing():
    bal = ShardBalancer(n_workers=16, n_pods=4, seed=0)
    # worker 3 becomes a straggler (4x slow)
    for _ in range(10):
        bal.observe(3, step_time=4.0, expected=1.0)
        for w in range(16):
            if w != 3:
                bal.observe(w, step_time=1.0, expected=1.0)
    rng = np.random.default_rng(0)
    picks = []
    for _ in range(200):
        homes = rng.choice(16, size=3, replace=False)
        picks.append(bal.assign(homes))
        bal.drain(0.3)
    counts = np.bincount(picks, minlength=16)
    healthy = np.delete(counts, 3)
    # the straggler receives far fewer shards than the mean healthy worker
    assert counts[3] < 0.5 * healthy.mean(), counts


def test_balls_and_bins_power_of_two():
    """Paper §I: max load drops from ~log n/log log n (d=1) to
    ~log log n/log d (d=2)."""
    n = 512
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    d1 = np.mean([int(max_load(k, n, 1)) for k in keys])
    d2 = np.mean([int(max_load(k, n, 2)) for k in keys])
    assert d2 < d1 - 1, (d1, d2)
    assert d2 <= theory_d(n, 2) + 3.0


def test_serve_engine_end_to_end():
    from repro.configs import get
    from repro.models import init_params
    from repro.serve import Request, ServeEngine

    cfg = get("llama3_8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fleet = FleetTopology(n_replicas=8, n_pods=2)
    router = PodRouter(fleet, service_rates(), policy="pod")
    rng = np.random.default_rng(0)
    prefix_homes = {i: rng.choice(8, size=3, replace=False)
                    for i in range(4)}
    eng = ServeEngine(cfg, params, fleet, router, prefix_homes, max_batch=4)
    reqs = [Request(rid=i, prefix_id=i % 4,
                    prompt=rng.integers(0, cfg.vocab, size=3),
                    max_new=4, arrival=0) for i in range(12)]
    eng.submit(reqs)
    stats = eng.run(until_done=12, max_ticks=500)
    assert len(stats.completions) == 12
    assert all(c > 0 for c in stats.completions)
    assert stats.probes_per_decision == 11          # 3 locals + d=8
    for r in eng.done:
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.generated)


def test_serve_engine_scenario_arrival_trace():
    """Scenario-driven load replay: a bursty (MMPP) arrival-count trace is
    fed through run_arrivals and every request completes."""
    from repro.configs import get
    from repro.models import init_params
    from repro.scenarios import TrafficSpec, arrival_counts
    from repro.serve import Request, ServeEngine

    cfg = get("llama3_8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fleet = FleetTopology(n_replicas=8, n_pods=2)
    router = PodRouter(fleet, service_rates(), policy="pod")
    rng = np.random.default_rng(0)
    prefix_homes = {i: rng.choice(8, size=3, replace=False) for i in range(4)}
    eng = ServeEngine(cfg, params, fleet, router, prefix_homes, max_batch=4)

    schedule = arrival_counts(TrafficSpec(kind="mmpp", burst=4.0,
                                          p_enter=0.2, p_exit=0.2),
                              T=10, mean_per_tick=1.0, seed=3)
    rid = iter(range(10_000))

    def make_request(tick):
        i = next(rid)
        return Request(rid=i, prefix_id=i % 4,
                       prompt=rng.integers(0, cfg.vocab, size=3),
                       max_new=3, arrival=tick)

    stats = eng.run_arrivals(schedule, make_request, max_ticks=500)
    assert len(stats.completions) == int(schedule.sum())
    assert all(c > 0 for c in stats.completions)
