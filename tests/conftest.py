import os
import sys

# never force multi-device here: smoke tests and benches must see 1 device
# (the dry-run sets its own XLA_FLAGS in a subprocess).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
