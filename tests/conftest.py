import os
import sys

# never force multi-device here: smoke tests and benches must see 1 device
# (the dry-run sets its own XLA_FLAGS in a subprocess).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    # property tests degrade to deterministic randomized replay (see stub)
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
