"""Property-based tests (hypothesis) for the routing primitives."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    Cluster,
    PodSpec,
    lex_argmin,
    locality_class,
    masked_draws,
    pod_candidates,
    route_pod_candidates,
    sample_locals,
    sample_rack_peer,
    sample_remote_peer,
)

SMALL = settings(max_examples=25, deadline=None)


@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(2, 8))
@SMALL
def test_lex_argmin_matches_numpy_lexsort(seed, b, m):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 4, (b, m)).astype(np.float32)   # force ties
    tb1 = rng.integers(0, 3, (b, m)).astype(np.float32)
    mask = rng.random((b, m)) < 0.8
    mask[:, 0] = True                                       # non-empty rows
    got = np.asarray(lex_argmin(jnp.asarray(vals), jnp.asarray(tb1),
                                mask=jnp.asarray(mask)))
    for i in range(b):
        keys = [(vals[i, j], tb1[i, j], j) for j in range(m) if mask[i, j]]
        want = min(keys)[2]
        assert got[i] == want


@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
@SMALL
def test_masked_draws_land_in_set(seed, k):
    rng = np.random.default_rng(seed)
    mask = rng.random((4, 20)) < 0.4
    idx, valid = masked_draws(jax.random.PRNGKey(seed),
                              jnp.asarray(mask), k)
    idx, valid = np.asarray(idx), np.asarray(valid)
    for b in range(4):
        if mask[b].any():
            assert valid[b].all()
            assert mask[b][idx[b]].all()
        else:
            assert not valid[b].any()


@given(st.integers(0, 2**31 - 1))
@SMALL
def test_pod_candidates_classes_and_membership(seed):
    c = Cluster(M=24, K=4)
    key = jax.random.PRNGKey(seed)
    locals_ = sample_locals(key, c, 8)
    cls = locality_class(c, locals_)
    ci, cc, cv = pod_candidates(key, c, locals_, cls, PodSpec(2, 4))
    ci, cc, cv = map(np.asarray, (ci, cc, cv))
    cls_np = np.asarray(cls)
    for b in range(8):
        for j in range(ci.shape[1]):
            if cv[b, j]:
                assert cls_np[b, ci[b, j]] == cc[b, j]


@given(st.integers(0, 2**31 - 1))
@SMALL
def test_route_pod_picks_min_weighted_workload(seed):
    c = Cluster(M=24, K=4)
    key = jax.random.PRNGKey(seed)
    W = jax.random.uniform(key, (c.M,)) * 10
    locals_ = sample_locals(key, c, 8)
    cls = locality_class(c, locals_)
    inv = jnp.array([10.0, 20.0, 50.0])
    ci, cc, cv = pod_candidates(key, c, locals_, cls, PodSpec(2, 4))
    sel, sel_cls = route_pod_candidates(key, W, ci, cc, cv, inv)
    scores = np.where(np.asarray(cv),
                      np.asarray(W)[np.asarray(ci)] * np.asarray(inv)[np.asarray(cc)],
                      np.inf)
    sel_score = np.asarray(W)[np.asarray(sel)] * np.asarray(inv)[np.asarray(sel_cls)]
    assert np.allclose(sel_score, scores.min(axis=1), rtol=1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
@SMALL
def test_rack_and_remote_peer_samplers(seed, k):
    c = Cluster(M=24, K=4)
    servers = jnp.arange(c.M, dtype=jnp.int32)
    rack = np.asarray(sample_rack_peer(jax.random.PRNGKey(seed), c, servers, k))
    rem = np.asarray(sample_remote_peer(jax.random.PRNGKey(seed), c, servers, k))
    rack_of = np.arange(c.M) // c.rack_size
    for m in range(c.M):
        assert (rack_of[rack[m]] == rack_of[m]).all()
        assert (rack[m] != m).all()
        assert (rack_of[rem[m]] != rack_of[m]).all()
        assert ((rem[m] >= 0) & (rem[m] < c.M)).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_queue_conservation_one_slot(seed):
    """Tasks are conserved slot-to-slot: dN = arrivals - completions."""
    import jax as j
    from repro.core import Rates, SimConfig, simulate
    c = Cluster(M=20, K=4)
    cfg = SimConfig(T=300, warmup=0)
    r = simulate("balanced_pandas_pod", c, Rates(0.1, 0.05, 0.02), 0.6,
                 j.random.PRNGKey(seed), cfg)
    # final N equals cumulative arrivals - completions (exact integers)
    # mean over run can't be checked this way; use totals:
    total_in = float(r.arrival_rate_hat) * float(cfg.T)
    total_out = float(r.throughput) * float(cfg.T)
    # final_N isn't exposed in SimResult; conservation holds if in-out>=0
    assert total_in - total_out > -1e-3
