"""Scenario engine: spec realization, placement skew, per-server rates,
refsim-vs-JAX agreement on a heterogeneous fleet, canonical padding
(one-compile sweep guard), and PodRouter-vs-refsim end-to-end agreement
on the heterogeneous kernel path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Cluster,
    PodSpec,
    Rates,
    SimConfig,
    inv_rate_matrix,
    locality_class,
    rate_matrix,
    reset_trace_count,
    route_balanced_pandas_full,
    simulate,
    trace_count,
)
from repro.core.refsim import simulate_bp_ref
from repro.scenarios import (
    SCENARIOS,
    FleetSpec,
    PlacementSpec,
    Scenario,
    TrafficProduct,
    TrafficSpec,
    WindowSpec,
    arrival_counts,
    canonical_a_max,
    canonical_pad,
    capacity_scale,
    cascading_stragglers,
    compose,
    correlated_outages,
    get_scenario,
    realize,
    sample_locals_scenario,
    speed_at,
    speed_trace,
    traffic_shape,
)

CLUSTER = Cluster(M=24, K=4)
RATES = Rates(0.05, 0.025, 0.01)


def test_registry_has_named_scenarios():
    assert len(SCENARIOS) >= 5
    for required in ("uniform", "slow_rack", "straggler_wave",
                     "diurnal_burst", "zipf_hotspot"):
        assert required in SCENARIOS
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("no_such_scenario")


# ---------------------------------------------------------------------------
# fleet axis
# ---------------------------------------------------------------------------


def test_speed_windows_compose_and_capacity_is_exact():
    spec = Scenario(
        "w", fleet=FleetSpec(rack_speeds=(0.5,), windows=(
            WindowSpec(t0=0.25, t1=0.75, mult=0.5, rack=0),
            WindowSpec(t0=0.50, t1=0.75, mult=0.0, rack=1),
        )))
    T = 1000
    scen, lam_cap = realize(spec, CLUSTER, RATES, T)
    R = CLUSTER.rack_size
    s0 = np.asarray(speed_at(scen, 0))            # [M, 3] per-class speeds
    assert s0[0] == pytest.approx([0.5] * 3) and s0[R] == pytest.approx([1.0] * 3)
    s_mid = np.asarray(speed_at(scen, 600))       # both windows active
    assert s_mid[0] == pytest.approx([0.25] * 3)  # 0.5 base * 0.5 window
    assert s_mid[R] == pytest.approx([0.0] * 3)   # rack 1 drained
    s_end = np.asarray(speed_at(scen, 900))       # recovered
    assert s_end[0] == pytest.approx([0.5] * 3)
    assert s_end[R] == pytest.approx([1.0] * 3)

    # capacity_scale integrates the piecewise-constant LOCAL trace exactly
    tr = speed_trace(scen, T)                     # [T, M, 3] host oracle
    assert capacity_scale(scen, T) == pytest.approx(tr[..., 0].mean(),
                                                    rel=1e-9)
    assert lam_cap == pytest.approx(RATES.alpha * CLUSTER.M
                                    * tr[..., 0].mean())


def test_uniform_scenario_is_the_seed_model():
    scen, lam_cap = realize(get_scenario(None), CLUSTER, RATES, 100)
    assert np.asarray(scen.base_speed).tolist() == [1.0] * CLUSTER.M
    assert scen.chunk_locals is None
    np.testing.assert_allclose(np.asarray(scen.lam_shape), 1.0)
    assert lam_cap == pytest.approx(CLUSTER.M * RATES.alpha)


# ---------------------------------------------------------------------------
# traffic axis
# ---------------------------------------------------------------------------


def test_traffic_shapes_are_mean_one_and_shaped():
    rng = np.random.default_rng(0)
    T = 4000
    for kind in ("stationary", "diurnal", "flash", "mmpp"):
        shape = traffic_shape(TrafficSpec(kind=kind), T, rng)
        assert shape.shape == (T,)
        assert shape.mean() == pytest.approx(1.0, rel=1e-5)
        assert (shape >= 0).all()
    flash = traffic_shape(TrafficSpec(kind="flash", t0=0.5, t1=0.6,
                                      peak=2.5), T, rng)
    assert flash[int(0.55 * T)] / flash[0] == pytest.approx(2.5, rel=1e-6)


def test_arrival_counts_deterministic_and_calibrated():
    spec = TrafficSpec(kind="mmpp")
    a = arrival_counts(spec, 5000, mean_per_tick=2.0, seed=7)
    b = arrival_counts(spec, 5000, mean_per_tick=2.0, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.mean() == pytest.approx(2.0, rel=0.15)


# ---------------------------------------------------------------------------
# placement axis
# ---------------------------------------------------------------------------


def test_zipf_placement_distribution_and_determinism():
    spec = get_scenario("zipf_hotspot")
    scen, _ = realize(spec, CLUSTER, RATES, 100)
    scen2, _ = realize(spec, CLUSTER, RATES, 100)
    # realization is deterministic in the scenario seed
    np.testing.assert_array_equal(np.asarray(scen.chunk_locals),
                                  np.asarray(scen2.chunk_locals))
    np.testing.assert_array_equal(np.asarray(scen.chunk_logits),
                                  np.asarray(scen2.chunk_logits))

    key = jax.random.PRNGKey(0)
    loc = np.asarray(sample_locals_scenario(key, CLUSTER, scen, 8000))
    loc2 = np.asarray(sample_locals_scenario(key, CLUSTER, scen, 8000))
    np.testing.assert_array_equal(loc, loc2)      # same key -> same draws

    # triples are valid server ids, distinct within a task
    assert loc.min() >= 0 and loc.max() < CLUSTER.M
    assert all(len(set(row)) == CLUSTER.n_replicas for row in loc)

    # distribution sanity: triple frequencies follow the Zipf law -> the
    # hottest triple appears ~p_0 of the time and far more often than under
    # uniform placement over the chunk catalog
    triples = [tuple(sorted(r)) for r in loc]
    top_frac = max(np.unique([hash(t) for t in triples],
                             return_counts=True)[1]) / len(triples)
    probs = np.exp(np.asarray(scen.chunk_logits))
    assert top_frac == pytest.approx(float(probs.max()), rel=0.2)
    C = probs.shape[0]
    assert top_frac > 5.0 / C                     # >> uniform 1/C


def test_pod_candidates_membership_under_zipf_placement():
    """masked_draws-backed pod sampling stays class-consistent when the
    locals come from the skewed placement law."""
    from repro.core import PodSpec, pod_candidates

    scen, _ = realize(get_scenario("zipf_hotspot"), CLUSTER, RATES, 100)
    key = jax.random.PRNGKey(3)
    locals_ = sample_locals_scenario(key, CLUSTER, scen, 64)
    cls = locality_class(CLUSTER, locals_)
    ci, cc, cv = pod_candidates(key, CLUSTER, locals_, cls, PodSpec(2, 4))
    ci, cc, cv = map(np.asarray, (ci, cc, cv))
    cls_np = np.asarray(cls)
    for b in range(64):
        for j in range(ci.shape[1]):
            if cv[b, j]:
                assert cls_np[b, ci[b, j]] == cc[b, j]


# ---------------------------------------------------------------------------
# per-server workload metric
# ---------------------------------------------------------------------------


def test_per_server_workload_routing_matches_numpy_oracle():
    rng = np.random.default_rng(1)
    M = CLUSTER.M
    speed = rng.uniform(0.25, 2.0, M).astype(np.float32)
    inv_m = np.asarray(inv_rate_matrix(RATES, jnp.asarray(speed)))
    # oracle: 1 / (speed_m * rate_c)
    want = 1.0 / (speed[:, None] * np.array(
        [RATES.alpha, RATES.beta, RATES.gamma])[None, :])
    np.testing.assert_allclose(inv_m, want, rtol=1e-5)

    Q = rng.integers(0, 12, (M, 3))
    W = (Q * inv_m).sum(axis=1).astype(np.float32)
    locals_ = sample_locals_scenario(jax.random.PRNGKey(4), CLUSTER,
                                     realize(get_scenario("uniform"),
                                             CLUSTER, RATES, 10)[0], 32)
    cls = locality_class(CLUSTER, locals_)
    tie = jax.random.uniform(jax.random.PRNGKey(5), (M,))
    sel, sel_cls = route_balanced_pandas_full(
        jnp.asarray(W), cls, jnp.asarray(inv_m), tie)
    sel, sel_cls = np.asarray(sel), np.asarray(sel_cls)
    cls_np = np.asarray(cls)
    scores = W[None, :] * inv_m[np.arange(M)[None, :], cls_np]    # [B, M]
    np.testing.assert_allclose(W[sel] * inv_m[sel, sel_cls],
                               scores.min(axis=1), rtol=1e-6)


# ---------------------------------------------------------------------------
# canonical padding: semantics preserved, one compile for the whole registry
# ---------------------------------------------------------------------------


def test_canonical_padding_preserves_scenario_semantics():
    """Padded realization == unpadded realization on everything observable:
    speed traces, capacity edge, traffic shape; pad chunks are never drawn."""
    pad = canonical_pad(CLUSTER)
    for name in ("uniform", "straggler_wave", "zipf_hotspot", "hetero_storm",
                 "network_degraded", "cascade_flash"):
        spec = get_scenario(name)
        T = 400
        raw, cap_raw = realize(spec, CLUSTER, RATES, T)
        can, cap_can = realize(spec, CLUSTER, RATES, T, pad=pad)
        assert cap_can == pytest.approx(cap_raw, rel=1e-9)
        np.testing.assert_array_equal(np.asarray(raw.lam_shape),
                                      np.asarray(can.lam_shape))
        np.testing.assert_allclose(speed_trace(can, T), speed_trace(raw, T))
        assert can.win_start.shape == (pad.n_windows,)
        assert can.chunk_logits.shape == (pad.n_chunks,)
        assert float(can.placement_on) == (
            1.0 if spec.placement.kind != "uniform" else 0.0)
        if spec.placement.kind != "uniform":
            # draws come from the real catalog only (pads have ~ -inf logits)
            loc = np.asarray(sample_locals_scenario(
                jax.random.PRNGKey(1), CLUSTER, can, 4000))
            real = {tuple(r) for r in np.asarray(raw.chunk_locals)}
            assert all(tuple(r) in real for r in loc)


def test_scenario_sweep_shares_one_compiled_signature():
    """The recompile-count regression guard: all 9 registry scenarios,
    realized with the registry-wide canonical pad and a shared a_max, must
    run the jit'd simulator on ONE compiled signature — the property that
    makes the scenario sweep's wall-clock kernel-bound instead of
    compile-bound."""
    cluster = Cluster(M=16, K=4)
    rates = Rates(0.05, 0.025, 0.01)
    # distinctive cfg so this test cannot collide with another test's
    # identically-shaped jit cache entry (which would hide a retrace)
    cfg = SimConfig(T=96, warmup=32, route_mode="batched", s_max=16)
    pad = canonical_pad(cluster)
    a_max = canonical_a_max(cluster, rates, cfg, 0.5)
    reset_trace_count()
    for name in SCENARIOS:
        r = simulate("balanced_pandas", cluster, rates, 0.5,
                     jax.random.PRNGKey(0), cfg, scenario=name,
                     pad=pad, a_max=a_max)
        assert np.isfinite(float(r.mean_tasks_in_system)), name
    assert trace_count() == 1, f"registry sweep retraced: {trace_count()}"
    # an unpadded window scenario changes the pytree shapes -> retrace;
    # this is exactly what the canonical pad removes
    simulate("balanced_pandas", cluster, rates, 0.5, jax.random.PRNGKey(0),
             cfg, scenario="rack_outage")
    assert trace_count() == 2


# ---------------------------------------------------------------------------
# refsim vs JAX on a heterogeneous fleet
# ---------------------------------------------------------------------------


def test_refsim_and_jax_agree_on_heterogeneous_scenario():
    """Event-accurate numpy oracle vs the vectorized simulator on a
    slow-rack fleet: mean task count within 5% (acceptance criterion)."""
    slow = Scenario("slow_rack_test", fleet=FleetSpec(rack_speeds=(0.5,)))
    speed = np.ones(CLUSTER.M)
    speed[:CLUSTER.rack_size] = 0.5

    # load 0.55 keeps queue autocorrelation (and so seed-to-seed spread)
    # small enough that the 5% bar is ~4 sigma for these seed counts
    T, warmup, load = 16_000, 4_000, 0.55
    ref = np.mean([simulate_bp_ref(CLUSTER, RATES, load, T=T, warmup=warmup,
                                   seed=s, speed=speed).mean_tasks_in_system
                   for s in range(3)])
    cfg = SimConfig(T=T, warmup=warmup)
    jaxN = np.mean([float(simulate("balanced_pandas", CLUSTER, RATES, load,
                                   jax.random.PRNGKey(s), cfg,
                                   scenario=slow).mean_tasks_in_system)
                    for s in range(6)])
    assert abs(jaxN - ref) / ref < 0.05, (jaxN, ref)


# ---------------------------------------------------------------------------
# PodRouter end-to-end on the heterogeneous kernel path
# ---------------------------------------------------------------------------


def _podrouter_closed_loop(rate_m, speed, load, T, warmup, seed,
                           d_rack=2, d_remote=6):
    """Drive PodRouter through refsim's slotted loop: per-arrival routing
    (each arrival sees the previous one's queues, like refsim), own-queue
    local>rack>remote service at per-server speed, Q decremented at service
    start (router.complete mirrors refsim's bookkeeping).  Returns the
    post-warmup mean tasks in system."""
    from repro.sched import FleetTopology, PodRouter

    M, R = CLUSTER.M, CLUSTER.rack_size
    fleet = FleetTopology(n_replicas=M, n_pods=CLUSTER.K)
    router = PodRouter(fleet, RATES, policy="pod",
                       pod=PodSpec(d_rack, d_remote), seed=seed,
                       rate_matrix=rate_m)
    assert (router.heterogeneous == (rate_m is not None))
    rng = np.random.default_rng(seed)
    class_p = np.array([RATES.alpha, RATES.beta, RATES.gamma])
    lam = load * RATES.alpha * speed.sum()
    counts = np.zeros((M, 3), np.int64)       # queued-only, mirrors router.Q
    busy = np.zeros(M, bool)
    rem = np.zeros(M)
    sum_N, slots = 0.0, 0
    for t in range(T):
        rem[busy] -= speed[busy]
        done = busy & (rem <= 0)
        busy &= ~done
        starts_m, starts_c = [], []
        for m in np.where(~busy & (speed > 0))[0]:
            for c in range(3):
                if counts[m, c] > 0:
                    counts[m, c] -= 1
                    starts_m.append(m)
                    starts_c.append(c)
                    busy[m] = True
                    rem[m] = rng.geometric(class_p[c])   # speed-1 work units
                    break
        if starts_m:
            router.complete(np.array(starts_m), np.array(starts_c))
        for _ in range(rng.poisson(lam)):
            locals_ = rng.choice(M, size=CLUSTER.n_replicas, replace=False)
            sel = int(router.route(locals_[None, :])[0])
            c = (0 if sel in locals_
                 else 1 if (locals_ // R == sel // R).any() else 2)
            counts[sel, c] += 1
        if t >= warmup:
            sum_N += counts.sum() + busy.sum()
            slots += 1
    return sum_N / slots


def test_podrouter_hetero_kernel_path_matches_refsim():
    """Acceptance criterion: PodRouter with a slow-rack [M, 3] rate matrix —
    now routed through the Pallas kernels, no plain-JAX fallback — must
    reproduce the event-accurate refsim's completion-time stats (mean tasks
    in system, i.e. mean completion time via Little's law) within the
    existing 5% tolerance."""
    speed = np.ones(CLUSTER.M)
    speed[:CLUSTER.rack_size] = 0.5
    rm = np.asarray(rate_matrix(RATES, jnp.asarray(speed)))

    # load 0.45: BP-Pod on a slow rack mixes slowly at higher loads
    # (per-seed means of the refsim are heavy-tailed at 0.55), so run where
    # relaxation is fast enough that the 5% bar is well clear of seed noise
    T, warmup, load = 10_000, 2_500, 0.45
    router_N = np.mean([
        _podrouter_closed_loop(rm, speed, load, T, warmup, seed=s)
        for s in range(3)])
    ref_N = np.mean([
        simulate_bp_ref(CLUSTER, RATES, load, T=T, warmup=warmup, seed=s,
                        d_rack=2, d_remote=6, pod=True,
                        speed=speed).mean_tasks_in_system
        for s in range(8)])
    assert abs(router_N - ref_N) / ref_N < 0.05, (router_N, ref_N)


def test_podrouter_hetero_path_equals_homogeneous_on_identical_rows():
    """With identical rate-matrix rows the unified kernel path must be
    bit-identical to the homogeneous router: same selections, same Q, same
    workloads, for both policies."""
    from repro.sched import FleetTopology, PodRouter

    M = CLUSTER.M
    fleet = FleetTopology(n_replicas=M, n_pods=CLUSTER.K)
    rm = np.asarray(rate_matrix(RATES, jnp.ones(M)))     # rows == class rates
    rng = np.random.default_rng(7)
    for policy in ("pod", "full"):
        het = PodRouter(fleet, RATES, policy=policy, seed=3, rate_matrix=rm)
        hom = PodRouter(fleet, RATES, policy=policy, seed=3)
        assert het.heterogeneous and not hom.heterogeneous
        for _ in range(12):
            locals_ = rng.integers(0, M, (8, 3)).astype(np.int32)
            np.testing.assert_array_equal(het.route(locals_),
                                          hom.route(locals_.copy()))
        np.testing.assert_array_equal(np.asarray(het.Q), np.asarray(hom.Q))
        np.testing.assert_allclose(np.asarray(het.W), np.asarray(hom.W))
        assert het.stats.probes == hom.stats.probes


def test_heterogeneous_simulation_is_stable_at_moderate_load():
    """JAX-side sanity on slow_rack: BP-Pod is stable at 60% of the
    (speed-scaled) capacity region and throughput tracks arrivals."""
    cfg = SimConfig(T=12_000, warmup=4_000)   # slow rack lengthens warmup
    r = simulate("balanced_pandas_pod", CLUSTER, RATES, 0.6,
                 jax.random.PRNGKey(0), cfg, scenario="slow_rack")
    assert np.isfinite(float(r.mean_completion_slots))
    assert float(r.drift) < 1.6
    assert abs(float(r.throughput) / float(r.arrival_rate_hat) - 1) < 0.1


# ---------------------------------------------------------------------------
# compose() algebra
# ---------------------------------------------------------------------------


def _assert_scenario_data_equal(a, b):
    for x, y in zip(a, b):
        if x is None:
            assert y is None
        else:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_compose_with_uniform_is_identity():
    """compose(uniform, s) realizes to exactly s's arrays (every axis merge
    has `uniform` as its identity, and the XOR'd seed preserves s's rng)."""
    for name in ("slow_rack", "straggler_wave", "mmpp_bursty",
                 "zipf_hotspot", "network_degraded", "pod_flap"):
        s = get_scenario(name)
        c = compose("uniform", s)
        T = 500
        a, cap_a = realize(s, CLUSTER, RATES, T)
        b, cap_b = realize(c, CLUSTER, RATES, T)
        assert cap_b == pytest.approx(cap_a, rel=1e-12), name
        _assert_scenario_data_equal(a, b)
        # and from the left too (all merges treat uniform as identity)
        d, _ = realize(compose(s, "uniform"), CLUSTER, RATES, T)
        _assert_scenario_data_equal(a, d)


def test_compose_order_invariance_on_deterministic_axes():
    """Fleet merge (window union, speed product) and deterministic traffic
    products are order-invariant through realization."""
    T = 600
    pairs = [("slow_rack", "straggler_wave"),      # speeds x windows
             ("slow_rack", "flash_crowd"),         # fleet x traffic
             ("diurnal_burst", "flash_crowd"),     # deterministic product
             ("network_degraded", "rack_outage")]  # per-class x outage
    for na, nb in pairs:
        ab, cap_ab = realize(compose(na, nb), CLUSTER, RATES, T)
        ba, cap_ba = realize(compose(nb, na), CLUSTER, RATES, T)
        assert cap_ab == pytest.approx(cap_ba, rel=1e-9), (na, nb)
        np.testing.assert_allclose(np.asarray(ab.lam_shape),
                                   np.asarray(ba.lam_shape), rtol=1e-6)
        np.testing.assert_allclose(speed_trace(ab, T), speed_trace(ba, T),
                                   rtol=1e-6)


def test_compose_merges_every_axis():
    c = compose("slow_rack", "flash_crowd", "zipf_hotspot")
    assert c.name == "slow_rack+flash_crowd+zipf_hotspot"
    assert c.fleet.rack_speeds == (0.5,)
    assert c.placement.kind == "zipf"
    T = 1000
    scen, lam_cap = realize(c, CLUSTER, RATES, T)
    lam = np.asarray(scen.lam_shape, np.float64)
    assert lam.mean() == pytest.approx(1.0, rel=1e-5)
    # the flash step survives composition (single non-trivial factor)
    assert lam[int(0.55 * T)] / lam[0] == pytest.approx(2.5, rel=1e-5)
    assert scen.chunk_locals is not None
    R = CLUSTER.rack_size
    want_scale = (0.5 * R + (CLUSTER.M - R)) / CLUSTER.M
    closed = RATES.alpha * CLUSTER.M * want_scale
    # the composition carries zipf_hotspot's skewed catalog, so lam_cap is
    # the fluid-LP edge: at most the fleet-only closed form, and strictly
    # below it when the hot chunks' local tier binds (which it does here)
    assert 0 < lam_cap < closed

    # persistent speeds multiply elementwise on double composition
    cc = compose("slow_rack", "slow_rack")
    assert cc.fleet.rack_speeds == (0.25,)


def test_compose_traffic_product_is_renormalized_product():
    c = compose("diurnal_burst", "flash_crowd")
    assert isinstance(c.traffic, TrafficProduct)
    T = 2000
    rng = np.random.default_rng(0)
    d = traffic_shape(get_scenario("diurnal_burst").traffic, T, rng)
    f = traffic_shape(get_scenario("flash_crowd").traffic, T, rng)
    want = (d.astype(np.float64) * f)
    want = want / want.mean()
    got = traffic_shape(c.traffic, T, np.random.default_rng(1))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    assert got.mean() == pytest.approx(1.0, rel=1e-5)


def test_compose_rightmost_nonuniform_placement_wins():
    z15 = Scenario("z15", placement=PlacementSpec(kind="zipf", zipf_s=1.5))
    assert compose("zipf_hotspot", z15).placement.zipf_s == 1.5
    assert compose(z15, "zipf_hotspot").placement.zipf_s == 1.2
    assert compose(z15, "uniform").placement.zipf_s == 1.5  # uniform: no-op


def test_mixed_base_and_composed_sweep_shares_one_signature():
    """Acceptance: compose() of any two registry scenarios realizes to the
    canonical pytree signature (registry_limits reserves pairwise window
    headroom), and a mixed base+composed sweep compiles exactly once."""
    cluster = Cluster(M=16, K=4)
    rates = Rates(0.05, 0.025, 0.01)
    cfg = SimConfig(T=88, warmup=24, route_mode="batched", s_max=16)
    pad = canonical_pad(cluster)

    # worst-case pairwise window union fits the canonical shapes
    widest = max(SCENARIOS.values(), key=lambda s: len(s.fleet.windows))
    worst = compose(widest, widest, name="worst_case")
    uni, _ = realize(get_scenario("uniform"), cluster, rates, cfg.T, pad=pad)
    com, _ = realize(worst, cluster, rates, cfg.T, pad=pad)
    assert (jax.tree_util.tree_structure(uni)
            == jax.tree_util.tree_structure(com))
    for u, c in zip(uni, com):
        assert u.shape == c.shape and u.dtype == c.dtype

    composed = [compose("slow_rack", "flash_crowd"),
                compose("network_degraded", "zipf_hotspot"),
                compose("straggler_wave", "tor_cascade", name="wave_cascade")]
    sweep = list(SCENARIOS) + composed
    a_max = canonical_a_max(cluster, rates, cfg, 0.5,
                            scenarios=list(SCENARIOS.values()) + composed)
    reset_trace_count()
    for s in sweep:
        r = simulate("balanced_pandas", cluster, rates, 0.5,
                     jax.random.PRNGKey(0), cfg, scenario=s,
                     pad=pad, a_max=a_max)
        assert np.isfinite(float(r.mean_tasks_in_system)), s
    assert trace_count() == 1, f"mixed sweep retraced: {trace_count()}"


# ---------------------------------------------------------------------------
# per-class (network-tier) windows + correlated-failure generators
# ---------------------------------------------------------------------------


def test_network_degraded_scales_only_beta_gamma():
    T = 1000
    scen, lam_cap = realize(get_scenario("network_degraded"), CLUSTER,
                            RATES, T)
    s = np.asarray(speed_at(scen, T // 2))        # inside the window
    np.testing.assert_allclose(s[:, 0], 1.0)
    np.testing.assert_allclose(s[:, 1], 0.4, rtol=1e-6)
    np.testing.assert_allclose(s[:, 2], 0.25, rtol=1e-6)
    s_out = np.asarray(speed_at(scen, 0))         # outside
    np.testing.assert_allclose(s_out, 1.0)
    # the capacity edge is local-service-bound: beta/gamma-only degradation
    # must not move it
    _, lam_uni = realize(get_scenario("uniform"), CLUSTER, RATES, T)
    assert lam_cap == pytest.approx(lam_uni, rel=1e-12)


def test_out_of_range_rack_selector_is_loud():
    """A window targeting a rack the cluster doesn't have must raise at
    realization, not silently become an inert no-op event."""
    for w in (WindowSpec(t0=0.1, t1=0.2, mult=0.0, rack=CLUSTER.K),
              WindowSpec(t0=0.1, t1=0.2, mult=0.5,
                         rack_member=(CLUSTER.K, 0))):
        with pytest.raises(ValueError, match="targets rack"):
            realize(Scenario("bad", fleet=FleetSpec(windows=(w,))),
                    CLUSTER, RATES, 100)


def test_correlated_outages_generator():
    ws = correlated_outages(n_events=5, n_racks=4, seed=7)
    assert ws == correlated_outages(n_events=5, n_racks=4, seed=7)
    assert ws != correlated_outages(n_events=5, n_racks=4, seed=8)
    assert len(ws) == 5
    for w in ws:
        assert w.mult == 0.0 and 0 <= w.rack < 4
        assert 0.0 <= w.t0 < w.t1 <= 1.0
        assert w.class_mult == (0.0, 0.0, 0.0)    # whole-pod drain
        assert 0.02 * 0.999 <= w.t1 - w.t0 <= 0.20 + 1e-9


def test_cascading_stragglers_generator_and_realization():
    ws = cascading_stragglers(n_events=3, n_racks=4, seed=5)
    assert len(ws) == 6                            # straggler + ToR per event
    scen, _ = realize(Scenario("cg", fleet=FleetSpec(windows=ws)),
                      CLUSTER, RATES, 1000)
    wm = np.asarray(scen.win_mult)                 # [6, M, 3]
    R = CLUSTER.rack_size
    for e, (a, b) in enumerate(zip(ws[::2], ws[1::2])):
        assert a.rack_member is not None and a.rack_member[0] == b.rack
        assert (a.t0, a.t1) == (b.t0, b.t1)
        # straggler window: exactly one server, all tiers slowed
        hit = np.where((wm[2 * e] != 1.0).any(axis=1))[0]
        assert len(hit) == 1 and hit[0] // R == b.rack
        np.testing.assert_allclose(wm[2 * e, hit[0]], 0.25)
        # cascade window: the whole rack's beta tier only
        hit2 = np.where((wm[2 * e + 1] != 1.0).any(axis=1))[0]
        assert len(hit2) == R and (hit2 // R == b.rack).all()
        np.testing.assert_allclose(wm[2 * e + 1, hit2[0]], [1.0, 0.5, 1.0])


# ---------------------------------------------------------------------------
# +inf zero-rate contract (the old finite sentinel absorbed tasks)
# ---------------------------------------------------------------------------


def test_drained_empty_server_scores_inf_not_zero():
    from repro.core import pod_candidates, route_pod_candidates, weighted_score

    speed = np.ones((CLUSTER.M, 3), np.float32)
    speed[0] = 0.0                                 # server 0 fully drained
    inv_m = inv_rate_matrix(RATES, jnp.asarray(speed))
    assert not np.isfinite(np.asarray(inv_m)[0]).any()

    # the contract primitive: 0 workload x inf inverse rate -> inf, not NaN
    s = np.asarray(weighted_score(jnp.zeros(3), np.asarray(inv_m)[0]))
    assert np.isinf(s).all() and not np.isnan(s).any()

    # full-BP routing over an EMPTY fleet: a task local to the dead server
    # must route to a live replica (the ROADMAP bug: the finite sentinel
    # made the dead server score 0 and absorb one task per outage window)
    W = jnp.zeros(CLUSTER.M)
    locals_ = jnp.asarray([[0, 1, 2]], jnp.int32)
    cls = locality_class(CLUSTER, locals_)
    tie = jax.random.uniform(jax.random.PRNGKey(0), (CLUSTER.M,))
    sel, sel_cls = route_balanced_pandas_full(W, cls, inv_m, tie)
    assert int(sel[0]) in (1, 2)                   # live locals win
    assert int(sel_cls[0]) == 0

    # pod routing with the dead server in the candidate list
    key = jax.random.PRNGKey(1)
    ci, cc, cv = pod_candidates(key, CLUSTER, locals_, cls, PodSpec(2, 4))
    sel_p, _ = route_pod_candidates(key, W, ci, cc, cv, inv_m)
    assert int(sel_p[0]) != 0


def test_outage_window_does_not_absorb_tasks_end_to_end():
    """During a whole-rack drain the dead rack's queues must stay empty
    under BP routing (no task is ever routed to a drained server)."""
    cfg = SimConfig(T=2_000, warmup=200)
    spec = Scenario("drain", fleet=FleetSpec(windows=(
        WindowSpec(t0=0.0, t1=1.0, mult=0.0, rack=0),)))
    r = simulate("balanced_pandas", CLUSTER, RATES, 0.4,
                 jax.random.PRNGKey(2), cfg, scenario=spec)
    # Little's-law N stays finite and the run is stable: the drained rack
    # absorbed nothing (absorbed tasks would never complete -> drift >> 1)
    assert np.isfinite(float(r.mean_tasks_in_system))
    assert float(r.drift) < 1.5
    assert float(r.throughput) / float(r.arrival_rate_hat) > 0.9


# ---------------------------------------------------------------------------
# refsim vs JAX on a per-class-window scenario
# ---------------------------------------------------------------------------


def test_refsim_and_jax_agree_on_per_class_windows():
    """Event-accurate numpy oracle vs the vectorized simulator with beta
    and gamma tiers at half speed fleet-wide (a full-run per-class window
    on the JAX side, a constant [M, 3] speed matrix on the refsim side):
    mean task count within 5%."""
    spec = Scenario("nd_const", fleet=FleetSpec(windows=(
        WindowSpec(t0=0.0, t1=1.0, mult=(1.0, 0.5, 0.5), every=1),)))
    speed = np.ones((CLUSTER.M, 3))
    speed[:, 1:] = 0.5

    # load 0.45: with halved beta/gamma the chain mixes slowly above ~0.5
    # (stationary N is large and warmup-dominated on both sides); at 0.45
    # relaxation is fast and the 5% bar is several sigma for these seeds
    T, warmup, load = 12_000, 3_000, 0.45
    ref = np.mean([simulate_bp_ref(CLUSTER, RATES, load, T=T, warmup=warmup,
                                   seed=s, speed=speed).mean_tasks_in_system
                   for s in range(3)])
    cfg = SimConfig(T=T, warmup=warmup)
    jaxN = np.mean([float(simulate("balanced_pandas", CLUSTER, RATES, load,
                                   jax.random.PRNGKey(s), cfg,
                                   scenario=spec).mean_tasks_in_system)
                    for s in range(6)])
    assert abs(jaxN - ref) / ref < 0.05, (jaxN, ref)


# ---------------------------------------------------------------------------
# batched BP path through the Pallas kernels
# ---------------------------------------------------------------------------


def test_batched_kernel_path_agrees_with_sequential_on_hetero():
    """The route_mode="batched" BP path runs the fused route_commit
    megakernel; on a slow-rack fleet it must agree with the sequential
    plain-JAX path at the same tolerance the homogeneous
    batched-vs-sequential test uses."""
    cfg_s = SimConfig(T=6_000, warmup=1_500)
    cfg_b = SimConfig(T=6_000, warmup=1_500, route_mode="batched")
    for algo in ("balanced_pandas", "balanced_pandas_pod"):
        a = float(simulate(algo, CLUSTER, RATES, 0.6, jax.random.PRNGKey(3),
                           cfg_s, scenario="slow_rack").mean_completion_slots)
        b = float(simulate(algo, CLUSTER, RATES, 0.6, jax.random.PRNGKey(3),
                           cfg_b, scenario="slow_rack").mean_completion_slots)
        assert abs(a - b) / a < 0.25, (algo, a, b)


def test_batched_fused_path_agrees_with_sequential_under_flash():
    """The snapshot-herding regression, end to end: flash_crowd drives
    large multi-arrival slots (2.5x peak), exactly where the old batched
    path routed a whole burst against one workload snapshot and herded it
    onto the argmin server (inflating completion times far beyond the
    sequential path).  With in-kernel sequential commits the batched and
    sequential paths must agree for every batched algorithm — BP, BP-Pod,
    and JSQ-MW-Pod.  clip_fraction == 0 also locks the peak-aware
    resolve_a_max sizing: the flash peak must fit the arrival buffer."""
    cfg_s = SimConfig(T=6_000, warmup=1_500)
    cfg_b = SimConfig(T=6_000, warmup=1_500, route_mode="batched")
    for algo in ("balanced_pandas", "balanced_pandas_pod",
                 "jsq_maxweight_pod"):
        rs = simulate(algo, CLUSTER, RATES, 0.6, jax.random.PRNGKey(5),
                      cfg_s, scenario="flash_crowd")
        rb = simulate(algo, CLUSTER, RATES, 0.6, jax.random.PRNGKey(5),
                      cfg_b, scenario="flash_crowd")
        assert float(rs.clip_fraction) == 0.0, algo
        assert float(rb.clip_fraction) == 0.0, algo
        a = float(rs.mean_completion_slots)
        b = float(rb.mean_completion_slots)
        assert abs(a - b) / a < 0.25, (algo, a, b)


def test_batched_fused_path_agrees_with_refsim():
    """Acceptance criterion: the fused batched path vs the event-accurate
    numpy refsim oracle, which routes every arrival against queues that
    include all earlier arrivals in the slot — the semantics the megakernel
    now implements in-kernel.  At load 0.5 multi-arrival slots are routine,
    so snapshot herding would push N well past the 5% bar."""
    T, warmup, load = 12_000, 3_000, 0.5
    ref = np.mean([simulate_bp_ref(CLUSTER, RATES, load, T=T, warmup=warmup,
                                   seed=s).mean_tasks_in_system
                   for s in range(3)])
    cfg = SimConfig(T=T, warmup=warmup, route_mode="batched")
    jaxN = np.mean([float(simulate("balanced_pandas", CLUSTER, RATES, load,
                                   jax.random.PRNGKey(s),
                                   cfg).mean_tasks_in_system)
                    for s in range(6)])
    assert abs(jaxN - ref) / ref < 0.05, (jaxN, ref)


# ---------------------------------------------------------------------------
# peak-aware arrival-buffer sizing
# ---------------------------------------------------------------------------


def test_resolve_a_max_sizes_from_peak_intensity():
    """resolve_a_max bounds the Poisson tail at the PEAK slot intensity
    (lam * shape_peak), not the mean — sizing from the mean clipped
    arrivals in exactly the flash/diurnal scenarios the clip warnings
    exist for."""
    cfg = SimConfig(T=100, warmup=10)
    assert cfg.resolve_a_max(10.0, 5.0) == cfg.resolve_a_max(50.0)
    assert cfg.resolve_a_max(10.0, 5.0) > cfg.resolve_a_max(10.0)
    assert cfg.resolve_a_max(10.0, 1.0) == cfg.resolve_a_max(10.0)
    # explicit a_max still overrides the auto sizing
    assert dataclasses.replace(cfg, a_max=7).resolve_a_max(10.0, 5.0) == 7
    # the shared canonical width covers every registry scenario's peak:
    # at least as wide as the peakiest shape demands
    cluster = Cluster(M=16, K=4)
    am = canonical_a_max(cluster, RATES, cfg, 0.5)
    lam_cap = 0.5 * RATES.alpha * cluster.M
    peaks = []
    for spec in SCENARIOS.values():
        scen, _ = realize(spec, cluster, RATES, cfg.T)
        peaks.append(float(np.max(np.asarray(scen.lam_shape))))
    assert am >= cfg.resolve_a_max(lam_cap, max(peaks))
