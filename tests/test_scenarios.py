"""Scenario engine: spec realization, placement skew, per-server rates,
refsim-vs-JAX agreement on a heterogeneous fleet, canonical padding
(one-compile sweep guard), and PodRouter-vs-refsim end-to-end agreement
on the heterogeneous kernel path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Cluster,
    PodSpec,
    Rates,
    SimConfig,
    inv_rate_matrix,
    locality_class,
    rate_matrix,
    reset_trace_count,
    route_balanced_pandas_full,
    simulate,
    trace_count,
)
from repro.core.refsim import simulate_bp_ref
from repro.scenarios import (
    SCENARIOS,
    FleetSpec,
    Scenario,
    TrafficSpec,
    WindowSpec,
    arrival_counts,
    canonical_a_max,
    canonical_pad,
    capacity_scale,
    get_scenario,
    realize,
    sample_locals_scenario,
    speed_at,
    speed_trace,
    traffic_shape,
)

CLUSTER = Cluster(M=24, K=4)
RATES = Rates(0.05, 0.025, 0.01)


def test_registry_has_named_scenarios():
    assert len(SCENARIOS) >= 5
    for required in ("uniform", "slow_rack", "straggler_wave",
                     "diurnal_burst", "zipf_hotspot"):
        assert required in SCENARIOS
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("no_such_scenario")


# ---------------------------------------------------------------------------
# fleet axis
# ---------------------------------------------------------------------------


def test_speed_windows_compose_and_capacity_is_exact():
    spec = Scenario(
        "w", fleet=FleetSpec(rack_speeds=(0.5,), windows=(
            WindowSpec(t0=0.25, t1=0.75, mult=0.5, rack=0),
            WindowSpec(t0=0.50, t1=0.75, mult=0.0, rack=1),
        )))
    T = 1000
    scen, lam_cap = realize(spec, CLUSTER, RATES, T)
    R = CLUSTER.rack_size
    s0 = np.asarray(speed_at(scen, 0))
    assert s0[0] == pytest.approx(0.5) and s0[R] == pytest.approx(1.0)
    s_mid = np.asarray(speed_at(scen, 600))       # both windows active
    assert s_mid[0] == pytest.approx(0.25)        # 0.5 base * 0.5 window
    assert s_mid[R] == pytest.approx(0.0)         # rack 1 drained
    s_end = np.asarray(speed_at(scen, 900))       # recovered
    assert s_end[0] == pytest.approx(0.5) and s_end[R] == pytest.approx(1.0)

    # capacity_scale integrates the piecewise-constant trace exactly
    tr = speed_trace(scen, T)                     # [T, M] host oracle
    assert capacity_scale(scen, T) == pytest.approx(tr.mean(), rel=1e-9)
    assert lam_cap == pytest.approx(RATES.alpha * CLUSTER.M * tr.mean())


def test_uniform_scenario_is_the_seed_model():
    scen, lam_cap = realize(get_scenario(None), CLUSTER, RATES, 100)
    assert np.asarray(scen.base_speed).tolist() == [1.0] * CLUSTER.M
    assert scen.chunk_locals is None
    np.testing.assert_allclose(np.asarray(scen.lam_shape), 1.0)
    assert lam_cap == pytest.approx(CLUSTER.M * RATES.alpha)


# ---------------------------------------------------------------------------
# traffic axis
# ---------------------------------------------------------------------------


def test_traffic_shapes_are_mean_one_and_shaped():
    rng = np.random.default_rng(0)
    T = 4000
    for kind in ("stationary", "diurnal", "flash", "mmpp"):
        shape = traffic_shape(TrafficSpec(kind=kind), T, rng)
        assert shape.shape == (T,)
        assert shape.mean() == pytest.approx(1.0, rel=1e-5)
        assert (shape >= 0).all()
    flash = traffic_shape(TrafficSpec(kind="flash", t0=0.5, t1=0.6,
                                      peak=2.5), T, rng)
    assert flash[int(0.55 * T)] / flash[0] == pytest.approx(2.5, rel=1e-6)


def test_arrival_counts_deterministic_and_calibrated():
    spec = TrafficSpec(kind="mmpp")
    a = arrival_counts(spec, 5000, mean_per_tick=2.0, seed=7)
    b = arrival_counts(spec, 5000, mean_per_tick=2.0, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.mean() == pytest.approx(2.0, rel=0.15)


# ---------------------------------------------------------------------------
# placement axis
# ---------------------------------------------------------------------------


def test_zipf_placement_distribution_and_determinism():
    spec = get_scenario("zipf_hotspot")
    scen, _ = realize(spec, CLUSTER, RATES, 100)
    scen2, _ = realize(spec, CLUSTER, RATES, 100)
    # realization is deterministic in the scenario seed
    np.testing.assert_array_equal(np.asarray(scen.chunk_locals),
                                  np.asarray(scen2.chunk_locals))
    np.testing.assert_array_equal(np.asarray(scen.chunk_logits),
                                  np.asarray(scen2.chunk_logits))

    key = jax.random.PRNGKey(0)
    loc = np.asarray(sample_locals_scenario(key, CLUSTER, scen, 8000))
    loc2 = np.asarray(sample_locals_scenario(key, CLUSTER, scen, 8000))
    np.testing.assert_array_equal(loc, loc2)      # same key -> same draws

    # triples are valid server ids, distinct within a task
    assert loc.min() >= 0 and loc.max() < CLUSTER.M
    assert all(len(set(row)) == CLUSTER.n_replicas for row in loc)

    # distribution sanity: triple frequencies follow the Zipf law -> the
    # hottest triple appears ~p_0 of the time and far more often than under
    # uniform placement over the chunk catalog
    triples = [tuple(sorted(r)) for r in loc]
    top_frac = max(np.unique([hash(t) for t in triples],
                             return_counts=True)[1]) / len(triples)
    probs = np.exp(np.asarray(scen.chunk_logits))
    assert top_frac == pytest.approx(float(probs.max()), rel=0.2)
    C = probs.shape[0]
    assert top_frac > 5.0 / C                     # >> uniform 1/C


def test_pod_candidates_membership_under_zipf_placement():
    """masked_draws-backed pod sampling stays class-consistent when the
    locals come from the skewed placement law."""
    from repro.core import PodSpec, pod_candidates

    scen, _ = realize(get_scenario("zipf_hotspot"), CLUSTER, RATES, 100)
    key = jax.random.PRNGKey(3)
    locals_ = sample_locals_scenario(key, CLUSTER, scen, 64)
    cls = locality_class(CLUSTER, locals_)
    ci, cc, cv = pod_candidates(key, CLUSTER, locals_, cls, PodSpec(2, 4))
    ci, cc, cv = map(np.asarray, (ci, cc, cv))
    cls_np = np.asarray(cls)
    for b in range(64):
        for j in range(ci.shape[1]):
            if cv[b, j]:
                assert cls_np[b, ci[b, j]] == cc[b, j]


# ---------------------------------------------------------------------------
# per-server workload metric
# ---------------------------------------------------------------------------


def test_per_server_workload_routing_matches_numpy_oracle():
    rng = np.random.default_rng(1)
    M = CLUSTER.M
    speed = rng.uniform(0.25, 2.0, M).astype(np.float32)
    inv_m = np.asarray(inv_rate_matrix(RATES, jnp.asarray(speed)))
    # oracle: 1 / (speed_m * rate_c)
    want = 1.0 / (speed[:, None] * np.array(
        [RATES.alpha, RATES.beta, RATES.gamma])[None, :])
    np.testing.assert_allclose(inv_m, want, rtol=1e-5)

    Q = rng.integers(0, 12, (M, 3))
    W = (Q * inv_m).sum(axis=1).astype(np.float32)
    locals_ = sample_locals_scenario(jax.random.PRNGKey(4), CLUSTER,
                                     realize(get_scenario("uniform"),
                                             CLUSTER, RATES, 10)[0], 32)
    cls = locality_class(CLUSTER, locals_)
    tie = jax.random.uniform(jax.random.PRNGKey(5), (M,))
    sel, sel_cls = route_balanced_pandas_full(
        jnp.asarray(W), cls, jnp.asarray(inv_m), tie)
    sel, sel_cls = np.asarray(sel), np.asarray(sel_cls)
    cls_np = np.asarray(cls)
    scores = W[None, :] * inv_m[np.arange(M)[None, :], cls_np]    # [B, M]
    np.testing.assert_allclose(W[sel] * inv_m[sel, sel_cls],
                               scores.min(axis=1), rtol=1e-6)


# ---------------------------------------------------------------------------
# canonical padding: semantics preserved, one compile for the whole registry
# ---------------------------------------------------------------------------


def test_canonical_padding_preserves_scenario_semantics():
    """Padded realization == unpadded realization on everything observable:
    speed traces, capacity edge, traffic shape; pad chunks are never drawn."""
    pad = canonical_pad(CLUSTER)
    for name in ("uniform", "straggler_wave", "zipf_hotspot", "hetero_storm"):
        spec = get_scenario(name)
        T = 400
        raw, cap_raw = realize(spec, CLUSTER, RATES, T)
        can, cap_can = realize(spec, CLUSTER, RATES, T, pad=pad)
        assert cap_can == pytest.approx(cap_raw, rel=1e-9)
        np.testing.assert_array_equal(np.asarray(raw.lam_shape),
                                      np.asarray(can.lam_shape))
        np.testing.assert_allclose(speed_trace(can, T), speed_trace(raw, T))
        assert can.win_start.shape == (pad.n_windows,)
        assert can.chunk_logits.shape == (pad.n_chunks,)
        assert float(can.placement_on) == (
            1.0 if spec.placement.kind != "uniform" else 0.0)
        if spec.placement.kind != "uniform":
            # draws come from the real catalog only (pads have ~ -inf logits)
            loc = np.asarray(sample_locals_scenario(
                jax.random.PRNGKey(1), CLUSTER, can, 4000))
            real = {tuple(r) for r in np.asarray(raw.chunk_locals)}
            assert all(tuple(r) in real for r in loc)


def test_scenario_sweep_shares_one_compiled_signature():
    """The recompile-count regression guard: all 9 registry scenarios,
    realized with the registry-wide canonical pad and a shared a_max, must
    run the jit'd simulator on ONE compiled signature — the property that
    makes the scenario sweep's wall-clock kernel-bound instead of
    compile-bound."""
    cluster = Cluster(M=16, K=4)
    rates = Rates(0.05, 0.025, 0.01)
    # distinctive cfg so this test cannot collide with another test's
    # identically-shaped jit cache entry (which would hide a retrace)
    cfg = SimConfig(T=96, warmup=32, route_mode="batched", s_max=16)
    pad = canonical_pad(cluster)
    a_max = canonical_a_max(cluster, rates, cfg, 0.5)
    reset_trace_count()
    for name in SCENARIOS:
        r = simulate("balanced_pandas", cluster, rates, 0.5,
                     jax.random.PRNGKey(0), cfg, scenario=name,
                     pad=pad, a_max=a_max)
        assert np.isfinite(float(r.mean_tasks_in_system)), name
    assert trace_count() == 1, f"registry sweep retraced: {trace_count()}"
    # an unpadded window scenario changes the pytree shapes -> retrace;
    # this is exactly what the canonical pad removes
    simulate("balanced_pandas", cluster, rates, 0.5, jax.random.PRNGKey(0),
             cfg, scenario="rack_outage")
    assert trace_count() == 2


# ---------------------------------------------------------------------------
# refsim vs JAX on a heterogeneous fleet
# ---------------------------------------------------------------------------


def test_refsim_and_jax_agree_on_heterogeneous_scenario():
    """Event-accurate numpy oracle vs the vectorized simulator on a
    slow-rack fleet: mean task count within 5% (acceptance criterion)."""
    slow = Scenario("slow_rack_test", fleet=FleetSpec(rack_speeds=(0.5,)))
    speed = np.ones(CLUSTER.M)
    speed[:CLUSTER.rack_size] = 0.5

    # load 0.55 keeps queue autocorrelation (and so seed-to-seed spread)
    # small enough that the 5% bar is ~4 sigma for these seed counts
    T, warmup, load = 16_000, 4_000, 0.55
    ref = np.mean([simulate_bp_ref(CLUSTER, RATES, load, T=T, warmup=warmup,
                                   seed=s, speed=speed).mean_tasks_in_system
                   for s in range(3)])
    cfg = SimConfig(T=T, warmup=warmup)
    jaxN = np.mean([float(simulate("balanced_pandas", CLUSTER, RATES, load,
                                   jax.random.PRNGKey(s), cfg,
                                   scenario=slow).mean_tasks_in_system)
                    for s in range(6)])
    assert abs(jaxN - ref) / ref < 0.05, (jaxN, ref)


# ---------------------------------------------------------------------------
# PodRouter end-to-end on the heterogeneous kernel path
# ---------------------------------------------------------------------------


def _podrouter_closed_loop(rate_m, speed, load, T, warmup, seed,
                           d_rack=2, d_remote=6):
    """Drive PodRouter through refsim's slotted loop: per-arrival routing
    (each arrival sees the previous one's queues, like refsim), own-queue
    local>rack>remote service at per-server speed, Q decremented at service
    start (router.complete mirrors refsim's bookkeeping).  Returns the
    post-warmup mean tasks in system."""
    from repro.sched import FleetTopology, PodRouter

    M, R = CLUSTER.M, CLUSTER.rack_size
    fleet = FleetTopology(n_replicas=M, n_pods=CLUSTER.K)
    router = PodRouter(fleet, RATES, policy="pod",
                       pod=PodSpec(d_rack, d_remote), seed=seed,
                       rate_matrix=rate_m)
    assert (router.heterogeneous == (rate_m is not None))
    rng = np.random.default_rng(seed)
    class_p = np.array([RATES.alpha, RATES.beta, RATES.gamma])
    lam = load * RATES.alpha * speed.sum()
    counts = np.zeros((M, 3), np.int64)       # queued-only, mirrors router.Q
    busy = np.zeros(M, bool)
    rem = np.zeros(M)
    sum_N, slots = 0.0, 0
    for t in range(T):
        rem[busy] -= speed[busy]
        done = busy & (rem <= 0)
        busy &= ~done
        starts_m, starts_c = [], []
        for m in np.where(~busy & (speed > 0))[0]:
            for c in range(3):
                if counts[m, c] > 0:
                    counts[m, c] -= 1
                    starts_m.append(m)
                    starts_c.append(c)
                    busy[m] = True
                    rem[m] = rng.geometric(class_p[c])   # speed-1 work units
                    break
        if starts_m:
            router.complete(np.array(starts_m), np.array(starts_c))
        for _ in range(rng.poisson(lam)):
            locals_ = rng.choice(M, size=CLUSTER.n_replicas, replace=False)
            sel = int(router.route(locals_[None, :])[0])
            c = (0 if sel in locals_
                 else 1 if (locals_ // R == sel // R).any() else 2)
            counts[sel, c] += 1
        if t >= warmup:
            sum_N += counts.sum() + busy.sum()
            slots += 1
    return sum_N / slots


def test_podrouter_hetero_kernel_path_matches_refsim():
    """Acceptance criterion: PodRouter with a slow-rack [M, 3] rate matrix —
    now routed through the Pallas kernels, no plain-JAX fallback — must
    reproduce the event-accurate refsim's completion-time stats (mean tasks
    in system, i.e. mean completion time via Little's law) within the
    existing 5% tolerance."""
    speed = np.ones(CLUSTER.M)
    speed[:CLUSTER.rack_size] = 0.5
    rm = np.asarray(rate_matrix(RATES, jnp.asarray(speed)))

    # load 0.45: BP-Pod on a slow rack mixes slowly at higher loads
    # (per-seed means of the refsim are heavy-tailed at 0.55), so run where
    # relaxation is fast enough that the 5% bar is well clear of seed noise
    T, warmup, load = 10_000, 2_500, 0.45
    router_N = np.mean([
        _podrouter_closed_loop(rm, speed, load, T, warmup, seed=s)
        for s in range(3)])
    ref_N = np.mean([
        simulate_bp_ref(CLUSTER, RATES, load, T=T, warmup=warmup, seed=s,
                        d_rack=2, d_remote=6, pod=True,
                        speed=speed).mean_tasks_in_system
        for s in range(8)])
    assert abs(router_N - ref_N) / ref_N < 0.05, (router_N, ref_N)


def test_podrouter_hetero_path_equals_homogeneous_on_identical_rows():
    """With identical rate-matrix rows the unified kernel path must be
    bit-identical to the homogeneous router: same selections, same Q, same
    workloads, for both policies."""
    from repro.sched import FleetTopology, PodRouter

    M = CLUSTER.M
    fleet = FleetTopology(n_replicas=M, n_pods=CLUSTER.K)
    rm = np.asarray(rate_matrix(RATES, jnp.ones(M)))     # rows == class rates
    rng = np.random.default_rng(7)
    for policy in ("pod", "full"):
        het = PodRouter(fleet, RATES, policy=policy, seed=3, rate_matrix=rm)
        hom = PodRouter(fleet, RATES, policy=policy, seed=3)
        assert het.heterogeneous and not hom.heterogeneous
        for _ in range(12):
            locals_ = rng.integers(0, M, (8, 3)).astype(np.int32)
            np.testing.assert_array_equal(het.route(locals_),
                                          hom.route(locals_.copy()))
        np.testing.assert_array_equal(np.asarray(het.Q), np.asarray(hom.Q))
        np.testing.assert_allclose(np.asarray(het.W), np.asarray(hom.W))
        assert het.stats.probes == hom.stats.probes


def test_heterogeneous_simulation_is_stable_at_moderate_load():
    """JAX-side sanity on slow_rack: BP-Pod is stable at 60% of the
    (speed-scaled) capacity region and throughput tracks arrivals."""
    cfg = SimConfig(T=12_000, warmup=4_000)   # slow rack lengthens warmup
    r = simulate("balanced_pandas_pod", CLUSTER, RATES, 0.6,
                 jax.random.PRNGKey(0), cfg, scenario="slow_rack")
    assert np.isfinite(float(r.mean_completion_slots))
    assert float(r.drift) < 1.6
    assert abs(float(r.throughput) / float(r.arrival_rate_hat) - 1) < 0.1
