"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the pod axis
crosses DCN; data/model stay inside a pod's ICI domain.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import;
tests run on 1 CPU device).
"""
from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # tests shrink the mesh together with REPRO_DRYRUN_DEVICES, e.g. "2x4"
    # (single pod) / "2x2x2" (multi-pod); production always gets 256/512.
    override = os.environ.get("REPRO_TEST_MESH")
    if override:
        dims = tuple(int(x) for x in override.split("x"))
        if multi_pod and len(dims) == 3:
            shape = dims
        elif not multi_pod and len(dims) == 2:
            shape = dims
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for subprocess tests (8 fake devices)."""
    return jax.make_mesh(shape, axes)
