"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record the compiled artifact's roofline inputs (deliverables e and g).

For each cell this produces artifacts/dryrun/<arch>__<shape>__<mesh>.json:
  memory_analysis   — per-device argument/output/temp/peak bytes (fit proof)
  cost_analysis     — per-device HLO FLOPs + bytes accessed
  collectives       — per-device operand bytes by collective op, parsed from
                      the post-SPMD compiled HLO text
  model_flops       — 6*N_active*D (train) / 2*N_active*D (inference)
  timings           — lower/compile wall seconds

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both     # every runnable cell
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (tests may shrink the fake-device pool; must happen before jax imports)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse
import functools
import json
import re
import time

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ArchConfig, ShapeSpec, all_cells, get, shape_applicable
from ..models import decode_step, forward, logits_fn
from ..roofline import analytic
from ..roofline import hlo as hlo_walk
from ..train.train_step import train_step
from . import specs as S
from .mesh import make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_TY_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _type_bytes(match) -> int:
    dt, dims = match.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def parse_collective_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-device collective traffic from the post-SPMD compiled HLO.

    Post-optimization HLO prints operands as name references, so sizes come
    from each collective's RESULT type (tuple members summed for -start
    forms — the (operand, result) alias pair is halved).  "wire bytes" uses
    the standard ring-algorithm per-chip traffic:
        all-reduce        2 R (g-1)/g      (R = result bytes, g = group)
        all-gather          R (g-1)/g      (R = gathered result)
        reduce-scatter      R (g-1)        (R = scattered result)
        all-to-all          R (g-1)/g
        collective-permute  R
    """
    res_bytes = {k: 0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        m = re.search(r"=\s+(\(?[^()]*(?:\([^)]*\))?[^()=]*?)\s+([a-z\-]+)\(", ls)
        if m is None:
            continue
        op = m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        restypes = m.group(1)
        R = sum(_type_bytes(t) for t in _TY_RE.finditer(restypes))
        if op.endswith("-start") and restypes.startswith("("):
            R //= 2  # (operand, result) alias tuple
        g = max(_group_size(ls, n_devices), 1)
        res_bytes[base] += R
        counts[base] += 1
        if base == "all-reduce":
            wire[base] += 2.0 * R * (g - 1) / g
        elif base in ("all-gather", "all-to-all"):
            wire[base] += R * (g - 1) / g
        elif base == "reduce-scatter":
            wire[base] += R * (g - 1)
        else:  # collective-permute
            wire[base] += float(R)
    return {"result_bytes": res_bytes, "wire_bytes": wire, "counts": counts,
            "total_wire_bytes": sum(wire.values()),
            "total_bytes": sum(res_bytes.values())}


def count_params(cfg: ArchConfig) -> dict:
    sds = jax.eval_shape(
        functools.partial(__import__("repro.models", fromlist=["init_params"])
                          .init_params, cfg), jax.random.PRNGKey(0))
    import math
    total = sum(math.prod(x.shape) for x in jax.tree.leaves(sds))
    routed = 0
    if cfg.n_experts:
        per_layer = 3 * cfg.n_experts * cfg.d_model * cfg.moe_d_ff
        routed = per_layer * cfg.n_layers
    active = total - routed
    if cfg.n_experts:
        active += routed * cfg.experts_per_token // cfg.n_experts
    return {"total": int(total), "active": int(active)}


def model_flops(cfg: ArchConfig, shape: ShapeSpec, n_active: int) -> float:
    """Matmul-only convention: 6*N*D train, 2*N*D inference forward,
    2*N*B decode (one token per sequence)."""
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def build_step(cfg: ArchConfig, shape: ShapeSpec, mesh):
    """Returns (jitted fn, abstract args tuple)."""
    if shape.kind == "train":
        state_sds, state_specs = S.abstract_train_state(cfg, mesh)
        batch = S.batch_specs(cfg, shape, mesh, with_labels=True)
        ocfg = S.opt_config_for(cfg)
        n_data = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                n_data *= mesh.shape[ax]
        from ..models import param_pspecs
        fn = jax.jit(
            functools.partial(train_step, cfg=cfg, opt_cfg=ocfg,
                              dispatch_groups=n_data,
                              microbatches=cfg.train_microbatches,
                              param_specs=param_pspecs(cfg)),
            donate_argnums=(0,))
        return fn, (state_sds, batch)

    params_sds, _ = S.abstract_params(cfg, mesh)
    if shape.kind == "prefill":
        batch = S.batch_specs(cfg, shape, mesh, with_labels=False)

        def prefill(params, batch):
            h, _ = forward(params, cfg, batch)
            logits = logits_fn(params["embed"], h[:, -1:])
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        return jax.jit(prefill), (params_sds, batch)

    cache_sds, _ = S.abstract_cache(cfg, shape, mesh)
    tokens, pos = S.decode_inputs(cfg, shape, mesh)

    n_data = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n_data *= mesh.shape[ax]

    def serve(params, cache, tokens, pos):
        # dispatch_groups=1 at decode: sharding the handful of decode tokens
        # over data would re-claim the axis expert-FF shards need (measured
        # 4.9 -> 243 GB regression; §Perf cell-3 iter-2, refuted).
        h, cache = decode_step(params, cfg, cache, tokens, pos,
                               dispatch_groups=1)
        logits = logits_fn(params["embed"], h)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return jax.jit(serve, donate_argnums=(1,)), (params_sds, cache_sds,
                                                 tokens, pos)


# --- §Perf hillclimb variants: named (rule overrides, config replaces) ----
# Each entry is one hypothesis from EXPERIMENTS.md §Perf; "baseline" == {}.
VARIANTS: dict = {
    "baseline": ({}, {}),
    # dense-TP cells: drop tensor parallelism, ZeRO-3 everything over BOTH
    # axes; fewer microbatches cut the per-step param re-gather count.
    "fsdp_pure": ({"embed_fsdp": ("data", "model"), "ff": None,
                   "heads": None, "vocab": None, "expert": None},
                  {"fsdp": True, "train_microbatches": 2}),
    "fsdp_mb1": ({"embed_fsdp": ("data", "model"), "ff": None,
                  "heads": None, "vocab": None, "expert": None},
                 {"fsdp": True, "train_microbatches": 1}),
    # MoE train: keep EP over model, shard expert-FF over data (EP^2) so
    # routed weights never re-gather; dense params stay ZeRO over data.
    "moe_ep2": ({"moe_ff": "data"}, {"train_microbatches": 2}),
    "moe_ep2_mb1": ({"moe_ff": "data"}, {"train_microbatches": 1}),
    # decode: no ZeRO re-gather at inference — experts sharded E x F.
    "decode_ep2": ({"embed_fsdp": None, "moe_ff": "data"}, {"fsdp": False}),
    # capacity-factor ablation (compute waste vs drop rate)
    "cf10": ({}, {"capacity_factor": 1.0}),
}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             save_hlo: bool = False, variant: str = "baseline") -> dict:
    cfg = get(arch)
    rule_over, cfg_over = VARIANTS[variant]
    if cfg_over:
        cfg = cfg.replace(**cfg_over)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    tag = "" if variant == "baseline" else f"@{variant}"
    rec = {"arch": arch + tag, "shape": shape_name, "mesh": mesh_kind,
           "family": cfg.family, "variant": variant}
    if not ok:
        rec["skipped"] = reason
        _write(out_dir, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    S.rules_for(cfg, shape, mesh, extra=rule_over)
    params = count_params(cfg)
    rec["params"] = params
    rec["model_flops"] = model_flops(cfg, shape, params["active"])
    rec["n_devices"] = mesh.size
    cc = analytic.cell_cost(cfg, shape)
    rec["analytic"] = {"flops_computed": cc.flops_computed,
                       "flops_useful": cc.flops_useful,
                       "hbm_bytes": cc.hbm_bytes,
                       "params_bytes": cc.params_bytes}

    # jax.set_mesh only exists in newer jax; Mesh is itself a context
    # manager with the semantics the lowering below needs (named axes
    # resolvable for NamedSharding / shard_map).
    with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
        fn, args = build_step(cfg, shape, mesh)
        t0 = time.time()
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        if hasattr(mem, "peak_memory_in_bytes"):
            rec["memory"]["peak_memory_in_bytes"] = int(mem.peak_memory_in_bytes)
        cost = compiled.cost_analysis()
        rec["cost_xla_flat"] = {k: float(cost[k]) for k in
                                ("flops", "bytes accessed", "transcendentals")
                                if k in cost}
        hlo = compiled.as_text()
        rec["collectives_flat"] = parse_collective_bytes(hlo, mesh.size)
        rec["collectives"] = hlo_walk.collective_summary(hlo, mesh.size)
        rec["hlo_lines"] = hlo.count("\n")
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            hname = (arch + tag).replace('@', '_AT_')
            with open(f"{out_dir}/{hname}__{shape_name}__{mesh_kind}.hlo", "w") as f:
                f.write(hlo)
        del hlo
    rec["timings"] = {"lower_s": t1 - t0, "compile_s": t2 - t1}
    _write(out_dir, rec)
    return rec


def _write(out_dir: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    name = rec['arch'].replace('@', '_AT_')
    path = f"{out_dir}/{name}__{rec['shape']}__{rec['mesh']}.json"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] wrote {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for cfg, shape, ok, _ in all_cells():
            for mk in meshes:
                name = cfg.name.replace("-", "_").replace(".", "_")
                run_cell(name, shape.name, mk, args.out, args.save_hlo)
    else:
        for mk in meshes:
            rec = run_cell(args.arch, args.shape, mk, args.out, args.save_hlo,
                           variant=args.variant)
            if "skipped" in rec:
                print(f"[dryrun] SKIP {args.arch} x {args.shape}: {rec['skipped']}")
            else:
                print(json.dumps({k: rec[k] for k in
                                  ("memory", "cost_xla_flat", "timings")},
                                 indent=1))
                print("collective wire bytes/device (trip-weighted):",
                      rec["collectives"]["total_wire_bytes"])


if __name__ == "__main__":
    main()
