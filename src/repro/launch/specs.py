"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape)`` returns the exact abstract inputs each cell's
step function takes — weak-type-correct, shardable, zero allocation — plus
the logical sharding rules the cell needs.  Modality frontends are STUBS
per the assignment: vlm cells get precomputed patch embeddings, encdec
cells get precomputed frame embeddings.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..models import cache_pspecs, init_cache, init_params, param_pspecs
from ..models.sharding import logical_pspec, set_rules
from ..optim.adamw import AdamWConfig, init_opt_state, opt_pspecs
from ..train.train_step import TrainState, init_train_state


def rules_for(cfg: ArchConfig, shape: ShapeSpec, mesh, extra=None) -> dict:
    """Per-cell logical-rule overrides (the baseline sharding plan;
    EXPERIMENTS.md §Perf hillclimbs pass ``extra`` via dryrun --variant)."""
    over = {}
    if cfg.fsdp:
        over["embed_fsdp"] = ("data",)          # ZeRO-3 params+opt over data
    if shape.kind == "decode":
        if shape.global_batch == 1:
            over["batch"] = None                 # cannot shard batch=1
            over["cache_seq"] = ("data", "model")
        else:
            over["cache_seq"] = "model"          # KV sharded over seq x model
    if extra:
        over.update(extra)
    return set_rules(over, mesh_axes=mesh.axis_names)


def opt_config_for(cfg: ArchConfig) -> AdamWConfig:
    # trillion-param archs: int8 moments are required to fit (DESIGN.md §4)
    return AdamWConfig(moment_dtype="int8" if cfg.fsdp else "float32")


def _axis_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axes is None:
        return 1
    if isinstance(axes, tuple):
        n = 1
        for a in axes:
            n *= sizes[a]
        return n
    return sizes[axes]


def sanitize_spec(spec: P, shape: tuple, mesh) -> P:
    """Drop mesh axes that do not evenly divide their dim (GSPMD rejects
    uneven *input* shardings).  Replication is the safe fallback; archs that
    hit this in a hot tensor get a per-arch rule instead (see rules_for)."""
    out = []
    for i, axes in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        n = _axis_size(mesh, axes)
        out.append(axes if n > 1 and shape[i] % n == 0 else
                   (axes if n == 1 else None))
    return P(*out)


def _sharded_sds(tree, spec_tree, mesh):
    def f(sds, spec):
        spec = sanitize_spec(spec, sds.shape, mesh)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(f, tree, spec_tree, is_leaf=lambda x: False)


def abstract_train_state(cfg: ArchConfig, mesh) -> tuple:
    """(TrainState SDS with shardings, TrainState PartitionSpecs)."""
    ocfg = opt_config_for(cfg)
    sds = jax.eval_shape(
        functools.partial(init_train_state, cfg, ocfg),
        jax.random.PRNGKey(0))
    pspecs = TrainState(params=param_pspecs(cfg),
                        opt=opt_pspecs(param_pspecs(cfg), ocfg))
    return _sharded_sds(sds, pspecs, mesh), pspecs


def abstract_params(cfg: ArchConfig, mesh):
    sds = jax.eval_shape(functools.partial(init_params, cfg),
                         jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg)
    return _sharded_sds(sds, pspecs, mesh), pspecs


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
                with_labels: bool) -> dict:
    B, S = shape.global_batch, shape.seq_len
    bsh = NamedSharding(mesh, logical_pspec("batch", None))
    esh = NamedSharding(mesh, logical_pspec("batch", None, None))
    S_txt = S - cfg.n_img_tokens if cfg.family == "vlm" else S
    batch = {"tokens": jax.ShapeDtypeStruct((B, S_txt), jnp.int32, sharding=bsh)}
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((B, S_txt), jnp.int32,
                                               sharding=bsh)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16, sharding=esh)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.bfloat16, sharding=esh)
    return batch


def abstract_cache(cfg: ArchConfig, shape: ShapeSpec, mesh):
    sds = jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, shape.seq_len))
    pspecs = cache_pspecs(cfg)
    return _sharded_sds(sds, pspecs, mesh), pspecs


def decode_inputs(cfg: ArchConfig, shape: ShapeSpec, mesh):
    B = shape.global_batch
    bsh = NamedSharding(mesh, logical_pspec("batch", None))
    psh = NamedSharding(mesh, logical_pspec("batch"))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=bsh)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=psh)
    return tokens, pos
