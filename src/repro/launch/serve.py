"""Serving launcher: replica fleet + PodRouter + real decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
        --requests 32 --replicas 8 --pods 2 --policy pod
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get
from ..models import init_params
from ..sched import FleetTopology, PodRouter, service_rates
from ..serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--policy", default="pod", choices=("pod", "full"))
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=args.smoke)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fleet = FleetTopology(n_replicas=args.replicas, n_pods=args.pods)
    router = PodRouter(fleet, service_rates(), policy=args.policy)
    rng = np.random.default_rng(0)
    prefix_homes = {i: rng.choice(args.replicas, size=3, replace=False)
                    for i in range(8)}
    eng = ServeEngine(cfg, params, fleet, router, prefix_homes)
    reqs = [Request(rid=i, prefix_id=int(rng.integers(0, 8)),
                    prompt=rng.integers(0, cfg.vocab, size=4),
                    max_new=args.max_new, arrival=0)
            for i in range(args.requests)]
    eng.submit(reqs)
    stats = eng.run(until_done=len(reqs))
    comp = np.array(stats.completions)
    print(f"[serve] {len(comp)} requests done; mean completion "
          f"{comp.mean():.1f} ticks (p95 {np.percentile(comp, 95):.0f}); "
          f"locality {stats.locality.round(3).tolist()}; "
          f"probes/decision {stats.probes_per_decision:.1f} "
          f"({args.policy})")


if __name__ == "__main__":
    main()
