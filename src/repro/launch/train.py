"""Training launcher.

CPU-scale entry point with the production code path: picks an arch config
(full or --smoke), builds the data pipeline, runs the fault-tolerant
Trainer (checkpoints, auto-resume, straggler telemetry).

    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
        --steps 100 --ckpt-dir /tmp/repro_train
"""
from __future__ import annotations

import argparse

from ..configs import get
from ..data import PipelineConfig, SyntheticLM
from ..optim import AdamWConfig
from ..train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=args.smoke)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps,
                       moment_dtype="int8" if args.int8_moments else "float32")
    pipe = SyntheticLM(PipelineConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         microbatches=args.microbatches,
                         grad_compress=args.grad_compress)
    out = Trainer(cfg, ocfg, tcfg, pipe).run()
    print(f"[train] done: final loss {out['losses'][-1]:.4f}, "
          f"mean step {1e3 * sum(out['step_times']) / len(out['step_times']):.0f} ms")


if __name__ == "__main__":
    main()
