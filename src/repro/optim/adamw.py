"""First-party AdamW (no optax in this container) with quantized moments.

moment_dtype:
  "float32" — standard AdamW.
  "bfloat16" — bf16 moments (2x smaller optimizer state).
  "int8"    — block-quantized int8 moments with per-block f32 scales
              (block = last axis, 128 wide): ~4x smaller state.  This is
              what lets kimi-k2-1t's optimizer state fit the multi-pod mesh
              (EXPERIMENTS.md §Dry-run memory table).

The optimizer state mirrors the param tree leaf-for-leaf, so the same
PartitionSpecs shard it (ZeRO when cfg.fsdp routes embed_fsdp -> data).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8


@dataclasses.dataclass
class QTensor:
    """Block-quantized int8 tensor, blocked along the LAST axis so the
    quantized layout is a pure reshape of the parameter layout — q inherits
    the parameter's sharding leaf-for-leaf (a flattened [n_blocks, 128]
    layout forced GSPMD to all-gather TB-scale f32 moments inside the
    optimizer update; measured on kimi-k2 — EXPERIMENTS.md §Perf).

    Linear mode (signed, first moment): x ~ q * scale.
    Log mode (positive, second moment): x ~ exp(offset + (q+127) * scale) —
    log-space keeps *relative* precision; linear int8 floors small v to 0
    and 1/sqrt(v) explodes (confirmed by divergence in early testing).

    q: int8 [..., n_blk, 128]; scale/offset: f32 [..., n_blk, 1].
    Registered as a pytree with ``log`` static (aux data)."""
    q: jnp.ndarray
    scale: jnp.ndarray
    offset: jnp.ndarray
    log: bool = False


jax.tree_util.register_pytree_node(
    QTensor,
    lambda t: ((t.q, t.scale, t.offset), t.log),
    lambda log, ch: QTensor(q=ch[0], scale=ch[1], offset=ch[2], log=log),
)


def _quantize(x: jnp.ndarray, log: bool) -> QTensor:
    last = x.shape[-1] if x.ndim else 1
    xr = x.reshape(x.shape if x.ndim else (1,))
    pad = (-last) % _BLOCK
    if pad:
        xr = jnp.pad(xr, [(0, 0)] * (xr.ndim - 1) + [(0, pad)],
                     constant_values=1e-30 if log else 0.0)
    blocks = xr.reshape(*xr.shape[:-1], -1, _BLOCK)
    if log:
        lb = jnp.log(jnp.maximum(blocks, 1e-30))
        lo = lb.min(axis=-1, keepdims=True)
        s = (lb.max(axis=-1, keepdims=True) - lo) / 254.0
        q = jnp.round((lb - lo) / jnp.maximum(s, 1e-12)) - 127.0
        return QTensor(q=q.astype(jnp.int8), scale=s.astype(F32),
                       offset=lo.astype(F32), log=True)
    s = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(s, 1e-20)).astype(jnp.int8)
    return QTensor(q=q, scale=s.astype(F32),
                   offset=jnp.zeros_like(s, F32), log=False)


def _dequantize(t: QTensor, shape, size) -> jnp.ndarray:
    if t.log:
        x = jnp.exp(t.offset + (t.q.astype(F32) + 127.0) * t.scale)
        x = jnp.where(x <= 2e-30, 0.0, x)
    else:
        x = t.q.astype(F32) * t.scale
    x = x.reshape(*x.shape[:-2], -1)           # unblock the last axis
    last = shape[-1] if shape else 1
    if x.shape[-1] != last:
        x = x[..., :last]
    return x.reshape(shape)


def _encode(x: jnp.ndarray, dtype: str, log: bool = False):
    if dtype == "int8":
        return _quantize(x, log)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(F32)


def _decode(x, like: jnp.ndarray, dtype: str) -> jnp.ndarray:
    if dtype == "int8":
        return _dequantize(x, like.shape, like.size)
    return x.astype(F32)


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(F32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    m = jax.tree.map(lambda p: _encode(jnp.zeros(p.shape, F32),
                                       cfg.moment_dtype, log=False), params)
    v = jax.tree.map(lambda p: _encode(jnp.zeros(p.shape, F32),
                                       cfg.moment_dtype, log=True), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def apply_update(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (params', state', metrics)."""
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(F32)
    b2c = 1 - cfg.b2 ** step.astype(F32)

    is_q = lambda x: isinstance(x, QTensor)

    def upd(p, g, m_enc, v_enc):
        g = g.astype(F32) * scale
        m = cfg.b1 * _decode(m_enc, p, cfg.moment_dtype) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_enc, p, cfg.moment_dtype) + (1 - cfg.b2) * g * g
        upd_ = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        decay = jnp.where(p.ndim >= 2, cfg.weight_decay, 0.0)  # no WD on norms
        newp = p.astype(F32) - lr * (upd_ + decay * p.astype(F32))
        return (newp.astype(p.dtype), _encode(m, cfg.moment_dtype, log=False),
                _encode(v, cfg.moment_dtype, log=True))

    # flatten by the params treedef; moments keep QTensor nodes as leaves
    p_flat, treedef = jax.tree.flatten(params)
    g_flat = jax.tree.leaves(grads)
    m_flat = jax.tree.flatten(state.m, is_leaf=is_q)[0]
    v_flat = jax.tree.flatten(state.v, is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
    newp = treedef.unflatten([t[0] for t in out])
    newm = treedef.unflatten([t[1] for t in out])
    newv = treedef.unflatten([t[2] for t in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return newp, OptState(step=step, m=newm, v=newv), metrics


def opt_pspecs(param_specs, cfg: AdamWConfig):
    """Optimizer-state PartitionSpecs mirroring the param specs.  int8
    moments are last-axis-blocked reshapes of the parameter, so each q/scale
    leaf keeps the parameter's spec (block dim inherits the old last-dim
    axis; the 128-wide tail and the scale's 1-wide tail are unsharded)."""
    from jax.sharding import PartitionSpec as P

    def spec_for(ps, log):
        if cfg.moment_dtype != "int8":
            return ps
        front = tuple(ps)[:-1] if len(ps) else ()
        last = tuple(ps)[-1] if len(ps) else None
        blocked = P(*front, last, None)
        return QTensor(q=blocked, scale=blocked, offset=blocked, log=log)

    is_p = lambda x: isinstance(x, P)
    mspec = jax.tree.map(lambda ps: spec_for(ps, False), param_specs,
                         is_leaf=is_p)
    vspec = jax.tree.map(lambda ps: spec_for(ps, True), param_specs,
                         is_leaf=is_p)
    return OptState(step=P(), m=mspec, v=vspec)
