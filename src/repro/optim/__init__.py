from .adamw import (AdamWConfig, OptState, QTensor, apply_update, cosine_lr,
                    global_norm, init_opt_state, opt_pspecs)
