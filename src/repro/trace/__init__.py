"""Trace-replay serving subsystem: ingest -> compile -> replay.

Three layers over one versioned arrival-log format (format.SCHEMA):

  ingest    ``format`` — ArrivalLog (JSONL + packed-npz round-trip),
            ``validate_log`` schema checking, and streaming slot-batch
            readers; ``synth`` — generators for production-shaped traces
            (diurnal x flash crowds x placement churn, Zipf popularity).
  compile   ``compile.scenario_from_trace`` — lower a log onto the
            scenario axes (binned lam_shape, per-churn-epoch placement
            catalog inside the canonical pad, fitted size law).
  replay    ``replay.ReplayEngine`` — high-throughput replay of a log
            through the fused route_commit megakernel: double-buffered
            host->device chunk transfer, donated arrival buffers, one
            compiled chunk step (imported lazily: the replay layer pulls
            in the simulator, which this package must not load at
            scenario-registry import time).

The canonical production-day trace is registered as the ``production_day``
registry scenario below — trace-backed scenarios realize within the
canonical ScenarioPad, so the one-compile sweep invariant holds across
synthetic and trace-lowered entries alike."""
from .format import (          # noqa: F401
    SCHEMA,
    ArrivalLog,
    SlotBatch,
    ensure_valid,
    iter_slot_batches,
    load,
    read_jsonl,
    read_npz,
    stream_slot_batches,
    validate_log,
    write_jsonl,
    write_npz,
)
from .synth import production_day, synth_trace  # noqa: F401
from .compile import (         # noqa: F401
    TracePlacement,
    TraceTraffic,
    arrival_rows,
    catalog_plan,
    fit_size_sigma,
    scenario_from_trace,
)

from ..scenarios.spec import SCENARIOS, register

if "production_day" not in SCENARIOS:
    # the source stays the cached thunk, so realize() resynthesizes nothing;
    # lowering itself synthesizes once here to fit the size law
    register(scenario_from_trace(production_day, name="production_day",
                                 seed=11))


def __getattr__(name):
    # replay imports the simulator (repro.core); loading it here would
    # cycle through scenarios/__init__'s tail import of this package
    if name in ("ReplayEngine", "ReplayResult", "replay_trace_count",
                "reset_replay_trace_count"):
        from . import replay
        return getattr(replay, name)
    raise AttributeError(name)
