"""Versioned arrival-log format: ingest, validation, streaming slot batches.

An :class:`ArrivalLog` is the canonical in-memory form of a timestamped,
chunk-addressed arrival trace — the raw material the trace->scenario
compiler (compile.py) lowers and the replay engine (replay.py) serves.
Two on-disk encodings round-trip exactly:

  JSONL   one header object (schema version + trace metadata) followed by
          one object per task: ``{"t": ..., "chunk": ..., "size": ...}``
          (plus ``"tenant"`` when present).  Human-greppable; streams.
  npz     packed columns (t / chunk / size / tenant) plus the same
          metadata — the compact interchange format.

Timestamps are float64 in ``[0, horizon)`` in the trace's own time unit
(the simulator is slot-grid agnostic: lowering bins ``t / horizon`` into
any T).  ``churn_t`` records placement-churn episode boundaries as
fractions of the horizon: at each boundary the chunk-id -> data mapping
changed upstream, so the compiler re-derives the placement catalog per
epoch.  ``validate_log`` is the schema checker CI runs on every trace
artifact (scripts/validate_trace.py)."""
from __future__ import annotations

import dataclasses
import json
from typing import Iterator, NamedTuple, Optional

import numpy as np

SCHEMA = "repro.trace/v1"


@dataclasses.dataclass(frozen=True, eq=False)
class ArrivalLog:
    """One arrival trace: sorted timestamps + per-task chunk / size / tenant.

    eq=False: comparing numpy columns element-wise has no useful dataclass
    semantics — use ``validate_log`` + explicit column comparison in tests.
    """

    name: str
    horizon: float                         # trace duration, own time unit
    t: np.ndarray                          # [N] f64 sorted, in [0, horizon)
    chunk: np.ndarray                      # [N] i64 chunk ids >= 0
    size: np.ndarray                       # [N] f32 size multipliers > 0
    tenant: Optional[np.ndarray] = None    # [N] i32, optional
    churn_t: tuple = ()                    # placement-churn boundaries (0,1)
    schema: str = SCHEMA

    @property
    def n_tasks(self) -> int:
        """Number of recorded arrivals."""
        return int(self.t.shape[0])

    @property
    def n_epochs(self) -> int:
        """Placement-churn epochs (boundaries + 1)."""
        return len(self.churn_t) + 1

    def epoch_bounds(self) -> np.ndarray:
        """[n_epochs + 1] f64 epoch boundaries in trace time units."""
        return np.asarray((0.0, *self.churn_t, 1.0)) * self.horizon

    def epoch_of(self) -> np.ndarray:
        """[N] int64 placement-epoch index of each task."""
        bounds = self.epoch_bounds()
        return np.clip(np.searchsorted(bounds, self.t, side="right") - 1,
                       0, self.n_epochs - 1)

    def slot_of(self, T: int) -> np.ndarray:
        """[N] int64 slot index on a T-slot grid over the horizon."""
        s = np.floor(self.t / self.horizon * T).astype(np.int64)
        return np.clip(s, 0, T - 1)

    def slot_counts(self, T: int) -> np.ndarray:
        """[T] int64 arrivals per slot (the compiler's lam_shape source)."""
        return np.bincount(self.slot_of(T), minlength=T)


def validate_log(log: ArrivalLog) -> list:
    """Schema check; returns a list of problem strings (empty == valid)."""
    errs = []
    if log.schema != SCHEMA:
        errs.append(f"schema {log.schema!r} != {SCHEMA!r}")
    if not (np.isfinite(log.horizon) and log.horizon > 0):
        errs.append(f"horizon {log.horizon!r} must be finite and > 0")
    n = log.t.shape[0]
    for col, want in (("chunk", n), ("size", n)):
        if getattr(log, col).shape[0] != want:
            errs.append(f"column {col!r} length != {want}")
    if log.tenant is not None and log.tenant.shape[0] != n:
        errs.append(f"column 'tenant' length != {n}")
    if n == 0:
        errs.append("empty trace (no tasks)")
        return errs
    if not np.all(np.diff(log.t) >= 0):
        errs.append("timestamps not sorted ascending")
    if float(log.t[0]) < 0 or float(log.t[-1]) >= log.horizon:
        errs.append("timestamps outside [0, horizon)")
    if not np.all(np.isfinite(log.t)):
        errs.append("non-finite timestamps")
    if np.any(log.chunk < 0):
        errs.append("negative chunk ids")
    if not np.all(np.isfinite(log.size)) or np.any(log.size <= 0):
        errs.append("sizes must be finite and > 0")
    ct = np.asarray(log.churn_t, np.float64)
    if ct.size and (np.any(ct <= 0) or np.any(ct >= 1)
                    or np.any(np.diff(ct) <= 0)):
        errs.append("churn_t must be strictly increasing fractions in (0,1)")
    return errs


def ensure_valid(log: ArrivalLog) -> ArrivalLog:
    """Pass the log through, raising ValueError listing schema errors."""
    errs = validate_log(log)
    if errs:
        raise ValueError("invalid arrival log: " + "; ".join(errs))
    return log


# ---------------------------------------------------------------------------
# JSONL encoding
# ---------------------------------------------------------------------------


def _header(log: ArrivalLog) -> dict:
    return {"schema": log.schema, "name": log.name,
            "horizon": float(log.horizon),
            "churn_t": [float(x) for x in log.churn_t],
            "n_tasks": log.n_tasks,
            "has_tenant": log.tenant is not None}


def write_jsonl(log: ArrivalLog, path) -> None:
    """Write the JSONL encoding: header object, then one task per line."""
    with open(path, "w") as f:
        f.write(json.dumps(_header(log)) + "\n")
        tenant = log.tenant
        for i in range(log.n_tasks):
            rec = {"t": float(log.t[i]), "chunk": int(log.chunk[i]),
                   "size": float(log.size[i])}
            if tenant is not None:
                rec["tenant"] = int(tenant[i])
            f.write(json.dumps(rec) + "\n")


def read_jsonl(path) -> ArrivalLog:
    """Read the JSONL encoding back (exact round-trip of write_jsonl)."""
    with open(path) as f:
        head = json.loads(next(f))
        t, chunk, size, tenant = [], [], [], []
        for line in f:
            if not line.strip():
                continue
            rec = json.loads(line)
            t.append(rec["t"])
            chunk.append(rec["chunk"])
            size.append(rec["size"])
            if head.get("has_tenant"):
                tenant.append(rec["tenant"])
    return ArrivalLog(
        name=head.get("name", "unnamed"),
        horizon=float(head["horizon"]),
        t=np.asarray(t, np.float64),
        chunk=np.asarray(chunk, np.int64),
        size=np.asarray(size, np.float32),
        tenant=np.asarray(tenant, np.int32) if head.get("has_tenant")
        else None,
        churn_t=tuple(head.get("churn_t", ())),
        schema=head.get("schema", "missing"))


# ---------------------------------------------------------------------------
# Packed-npz encoding
# ---------------------------------------------------------------------------


def write_npz(log: ArrivalLog, path) -> None:
    """Write the packed-npz encoding (same columns as JSONL)."""
    cols = dict(t=log.t.astype(np.float64),
                chunk=log.chunk.astype(np.int64),
                size=log.size.astype(np.float32),
                schema=np.asarray(log.schema),
                name=np.asarray(log.name),
                horizon=np.asarray(log.horizon, np.float64),
                churn_t=np.asarray(log.churn_t, np.float64))
    if log.tenant is not None:
        cols["tenant"] = log.tenant.astype(np.int32)
    np.savez_compressed(path, **cols)


def read_npz(path) -> ArrivalLog:
    """Read the packed-npz encoding back (exact round-trip)."""
    with np.load(path, allow_pickle=False) as z:
        return ArrivalLog(
            name=str(z["name"]),
            horizon=float(z["horizon"]),
            t=np.asarray(z["t"], np.float64),
            chunk=np.asarray(z["chunk"], np.int64),
            size=np.asarray(z["size"], np.float32),
            tenant=(np.asarray(z["tenant"], np.int32)
                    if "tenant" in z.files else None),
            churn_t=tuple(np.asarray(z["churn_t"], np.float64).tolist()),
            schema=str(z["schema"]))


def load(path) -> ArrivalLog:
    """Read either encoding, dispatched on the file extension."""
    p = str(path)
    if p.endswith(".jsonl"):
        return read_jsonl(p)
    if p.endswith(".npz"):
        return read_npz(p)
    raise ValueError(f"unknown trace extension (want .jsonl or .npz): {p}")


# ---------------------------------------------------------------------------
# Streaming slot-batch reader
# ---------------------------------------------------------------------------


class SlotBatch(NamedTuple):
    """A fixed-width window of slots with its arrivals (host-side).

    slot0    first slot of the batch (multiples of batch_slots)
    counts   [batch_slots] int64 arrivals per slot
    slot     [n] int32 slot of each arrival, RELATIVE to slot0
    chunk    [n] int64
    size     [n] f32
    tenant   [n] i32 or None
    """

    slot0: int
    counts: np.ndarray
    slot: np.ndarray
    chunk: np.ndarray
    size: np.ndarray
    tenant: Optional[np.ndarray]


def iter_slot_batches(log: ArrivalLog, T: int,
                      batch_slots: int) -> Iterator[SlotBatch]:
    """Chunk an in-memory log into fixed-size slot batches (sorted input:
    one searchsorted per boundary, no per-task Python work)."""
    slots = log.slot_of(T)
    for s0 in range(0, T, batch_slots):
        s1 = min(s0 + batch_slots, T)
        lo = int(np.searchsorted(slots, s0, side="left"))
        hi = int(np.searchsorted(slots, s1, side="left"))
        sl = (slots[lo:hi] - s0).astype(np.int32)
        yield SlotBatch(
            slot0=s0,
            counts=np.bincount(sl, minlength=batch_slots),
            slot=sl,
            chunk=log.chunk[lo:hi],
            size=log.size[lo:hi],
            tenant=None if log.tenant is None else log.tenant[lo:hi])


def stream_slot_batches(path, T: int,
                        batch_slots: int) -> Iterator[SlotBatch]:
    """Stream a JSONL log into slot batches WITHOUT materializing the whole
    trace: holds one batch of tasks at a time (the ingest path for logs
    larger than host memory).  npz paths fall back to the in-memory
    iterator (npz is loaded whole by construction)."""
    p = str(path)
    if not p.endswith(".jsonl"):
        yield from iter_slot_batches(load(p), T, batch_slots)
        return
    with open(p) as f:
        head = json.loads(next(f))
        horizon = float(head["horizon"])
        has_tenant = bool(head.get("has_tenant"))
        width = horizon / T

        def flush(s0, buf):
            sl = np.asarray([b[0] for b in buf], np.int32) - s0
            return SlotBatch(
                slot0=s0,
                counts=np.bincount(sl, minlength=batch_slots),
                slot=sl,
                chunk=np.asarray([b[1] for b in buf], np.int64),
                size=np.asarray([b[2] for b in buf], np.float32),
                tenant=(np.asarray([b[3] for b in buf], np.int32)
                        if has_tenant else None))

        s0, buf = 0, []
        for line in f:
            if not line.strip():
                continue
            rec = json.loads(line)
            s = min(int(rec["t"] / width), T - 1)
            while s >= s0 + batch_slots:
                yield flush(s0, buf)
                s0, buf = s0 + batch_slots, []
            buf.append((s, rec["chunk"], rec["size"],
                        rec.get("tenant", 0)))
        while s0 < T:
            yield flush(s0, buf)
            s0, buf = s0 + batch_slots, []
