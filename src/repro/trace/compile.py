"""Lower an ArrivalLog into the scenario algebra (trace -> Scenario).

The compiler maps the three trace ingredients onto the three scenario axes
plus the size axis, entirely within the canonical ``ScenarioPad``
signature so trace-backed scenarios ride the one-compile sweep unchanged:

  lam_shape   timestamps binned into the simulator's T-slot grid and
              normalized to mean 1 (:class:`TraceTraffic`) — the load knob
              then scales absolute intensity exactly like synthetic shapes.
  placement   the catalog is derived from OBSERVED chunk ids: each
              placement-churn epoch gets its own catalog segment (churn ==
              the mapping changed, so popularity mass moves to fresh rows),
              sized to fit the canonical ``chunks_per_server * M`` row
              budget.  Within an epoch the most-popular chunks get
              individual rows ("head"); the cold tail is folded into a few
              shared rows by ``chunk_id % n_tail`` (:class:`TracePlacement`).
              Replica triples are drawn per row at realization — placement
              structure comes from the trace, server assignment from the
              scenario seed, exactly like the synthetic Zipf catalog.
  sizes       a mean-1 lognormal is fitted to the observed multipliers
              (sigma = std of log sizes) and threaded into service progress
              via ``ScenarioData.size_mu / size_sigma`` — per-task sizes
              enter the simulator as the law they were drawn from.

Lowering is deterministic: the shape/catalog *structure* depends only on
the log and the row budget, and all random draws (replica triples) come
from the realize() rng chain, so the same trace + seed realizes to a
bit-identical Scenario pytree (tests/test_trace.py guards this)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Union

import numpy as np

from ..scenarios.spec import Scenario, SizeSpec, _traffic_from_parts
from .format import ArrivalLog

_TINY = 1e-12         # mass floor: empty rows get ~ -27 logits, never drawn


def _resolve(source) -> ArrivalLog:
    return source() if callable(source) else source


@dataclasses.dataclass(frozen=True, eq=False)
class TraceTraffic:
    """Traffic axis backed by a trace (duck-types TrafficSpec for
    build._shape_one via ``realize_shape``).  ``source`` is an ArrivalLog
    or a zero-arg thunk returning one (thunks keep registry entries lazy:
    the canonical production-day trace synthesizes on first realize)."""

    source: Union[ArrivalLog, Callable[[], ArrivalLog]]
    kind: str = "trace"
    smooth: float = 0.005       # moving-average window as a fraction of T

    @property
    def parts(self) -> tuple:
        """A recorded trace is always a non-trivial traffic factor."""
        return (self,)

    def merge(self, other):
        """Compose with another traffic shape (pointwise product)."""
        return _traffic_from_parts(self.parts + other.parts)

    def realize_shape(self, T: int, rng) -> np.ndarray:
        """[T] raw intensity estimate (no rng consumed — lowering a
        recorded trace is deterministic; traffic_shape normalizes to
        mean 1 downstream).

        The binned counts are themselves one sampling realization of the
        underlying intensity; feeding them to the simulator's Poisson
        arrivals raw would re-Poissonize that noise (a doubly-stochastic
        stream, overdispersed ~2x per slot vs the trace).  A moving
        average of ``smooth`` x T slots estimates the intensity instead —
        wide enough to kill per-slot shot noise, narrow enough (default
        0.5% of the horizon) to preserve diurnal ramps and flash crowds.
        ``smooth=0`` replays the raw counts."""
        del rng
        counts = _resolve(self.source).slot_counts(T).astype(np.float64)
        w = int(round(self.smooth * T))
        if w > 1:
            k = np.ones(w)
            counts = (np.convolve(counts, k, "same")
                      / np.convolve(np.ones(T), k, "same"))
        return counts


class CatalogPlan(NamedTuple):
    """Deterministic catalog structure for one epoch (host-side).

    head_ids   [H] chunk ids with individual rows, most popular first
    n_tail     shared tail rows folding the remaining cold chunks
    row0       this epoch's first global catalog row
    mass       [H + n_tail] f64 task mass per row (sums to epoch mass)
    """

    head_ids: np.ndarray
    n_tail: int
    row0: int
    mass: np.ndarray


def catalog_plan(log: ArrivalLog, budget: int) -> list:
    """Split the ``budget`` catalog rows across churn epochs.

    Rows go epoch-major; each epoch's share is proportional to its row
    budget (equal split, remainder to early epochs).  Within an epoch the
    top chunks by observed count get individual head rows; if the epoch
    has more distinct chunks than rows, 1/8 of its rows become shared
    tail rows (``chunk_id % n_tail``) carrying the leftover mass.
    Structure depends only on (log, budget) — no randomness — so the
    realized catalog and the replay row mapping always agree."""
    E = log.n_epochs
    if budget < E:
        raise ValueError(f"catalog budget {budget} < {E} churn epochs")
    share = [budget // E + (1 if e < budget % E else 0) for e in range(E)]
    epoch = log.epoch_of()
    plans, row0 = [], 0
    for e in range(E):
        rows_e = share[e]
        ids, counts = np.unique(log.chunk[epoch == e], return_counts=True)
        order = np.argsort(-counts, kind="stable")
        ids, counts = ids[order], counts[order]
        if ids.shape[0] <= rows_e:
            head, n_tail = ids, 0
            mass = counts.astype(np.float64)
            mass = np.pad(mass, (0, rows_e - mass.shape[0]))  # empty rows
        else:
            n_tail = max(1, rows_e // 8)
            head = ids[:rows_e - n_tail]
            # tail rows carry the ACTUAL mass their fold receives (the
            # same chunk_id % n_tail mapping arrival_rows applies), so the
            # realized popularity law and the replay row stream agree
            # row-for-row, not just in aggregate
            tail_ids = ids[rows_e - n_tail:]
            tail_counts = counts[rows_e - n_tail:]
            tail_mass = np.bincount((tail_ids % n_tail).astype(np.int64),
                                    weights=tail_counts.astype(np.float64),
                                    minlength=n_tail)
            mass = np.concatenate([
                counts[:rows_e - n_tail].astype(np.float64), tail_mass])
        plans.append(CatalogPlan(head_ids=head, n_tail=n_tail, row0=row0,
                                 mass=mass))
        row0 += rows_e
    return plans


def arrival_rows(log: ArrivalLog, budget: int) -> np.ndarray:
    """[N] int32 global catalog row of every task (the replay engine's
    chunk-id -> catalog lookup; inverse of catalog_plan's layout)."""
    plans = catalog_plan(log, budget)
    epoch = log.epoch_of()
    rows = np.empty(log.n_tasks, np.int32)
    for e, plan in enumerate(plans):
        m = epoch == e
        c = log.chunk[m]
        order = np.argsort(plan.head_ids, kind="stable")
        sorted_ids = plan.head_ids[order]
        pos = np.searchsorted(sorted_ids, c)
        pos = np.minimum(pos, max(sorted_ids.shape[0] - 1, 0))
        if sorted_ids.shape[0]:
            is_head = sorted_ids[pos] == c
            head_row = plan.row0 + order[pos]
        else:
            is_head = np.zeros(c.shape, bool)
            head_row = np.zeros(c.shape, np.int64)
        if plan.n_tail:
            tail_row = (plan.row0 + plan.head_ids.shape[0]
                        + c % plan.n_tail)
        else:
            tail_row = head_row     # head covers every observed chunk
        rows[m] = np.where(is_head, head_row, tail_row).astype(np.int32)
    return rows


@dataclasses.dataclass(frozen=True, eq=False)
class TracePlacement:
    """Placement axis backed by a trace (duck-types PlacementSpec for
    build._placement_arrays via ``realize_catalog``)."""

    source: Union[ArrivalLog, Callable[[], ArrivalLog]]
    chunks_per_server: int = 4             # canonical row budget / server
    kind: str = "trace"

    def merge(self, other):
        """Rightmost non-uniform wins — same contract as PlacementSpec."""
        return other if getattr(other, "kind", "uniform") != "uniform" \
            else self

    def budget(self, M: int) -> int:
        """Catalog-row budget for an M-server cluster."""
        return self.chunks_per_server * M

    @property
    def n_epochs(self) -> int:
        """Churn-epoch count (canonical-pad sizing; see registry_limits)."""
        return _resolve(self.source).n_epochs

    def realize_epochs(self, T: int) -> np.ndarray:
        """[T] int32 slot -> churn-epoch index (by slot midpoint)."""
        bounds = np.asarray(_resolve(self.source).churn_t, np.float64)
        frac = (np.arange(T) + 0.5) / T
        return np.searchsorted(bounds, frac, side="right").astype(np.int32)

    def realize_catalog(self, cluster, rng: np.random.Generator):
        """(logits [C], locals [C, n_rep], epoch_logits [E, C]).

        ``logits`` is the whole-trace popularity mass over the epoch-major
        catalog rows; ``epoch_logits[e]`` is the CONDITIONAL popularity
        while epoch e is active — mass only on epoch e's rows, normalized
        within the epoch — so the simulator reproduces the trace's
        per-instant skew instead of a mixture diluted across episodes.
        Replica triples are drawn from the realize() rng (distinct
        servers, uniform placement — trace logs address chunks, not
        servers, so server assignment is the scenario seed's)."""
        log = _resolve(self.source)
        plans = catalog_plan(log, self.budget(cluster.M))
        mass = np.concatenate([p.mass for p in plans])
        logits = np.log(np.maximum(mass, _TINY)
                        / max(log.n_tasks, 1)).astype(np.float32)
        C = mass.shape[0]
        epoch_logits = np.full((len(plans), C), np.log(_TINY), np.float32)
        for e, plan in enumerate(plans):
            rows = slice(plan.row0, plan.row0 + plan.mass.shape[0])
            epoch_logits[e, rows] = np.log(
                np.maximum(plan.mass, _TINY) / max(plan.mass.sum(), 1.0))
        order = np.argsort(rng.random((C, cluster.M)), axis=1)
        locals_ = order[:, :cluster.n_replicas].astype(np.int32)
        return logits, locals_, epoch_logits


def fit_size_sigma(log: ArrivalLog) -> float:
    """Log-space std of the observed size multipliers (0 when constant)."""
    return float(np.std(np.log(np.asarray(log.size, np.float64))))


def scenario_from_trace(source, *, name: Optional[str] = None,
                        chunks_per_server: int = 4,
                        seed: int = 0) -> Scenario:
    """Lower a trace (ArrivalLog or lazy thunk) into a Scenario."""
    log = _resolve(source)
    return Scenario(
        name=name or f"trace:{log.name}",
        traffic=TraceTraffic(source=source),
        placement=TracePlacement(source=source,
                                 chunks_per_server=chunks_per_server),
        sizes=SizeSpec(sigma=fit_size_sigma(log)),
        seed=seed,
        description=f"trace-lowered scenario from arrival log "
                    f"{log.name!r} ({log.n_tasks} tasks, "
                    f"{log.n_epochs} placement epochs)")
