"""Synthesize production-shaped arrival traces (one "production day").

The generator layers the ingredients real scheduler telemetry shows:

  diurnal base       sinusoidal intensity over the day
  flash crowds       short multiplicative spikes at random offsets
  placement churn    episode boundaries where the chunk-id -> data mapping
                     is reshuffled upstream (recorded in ``churn_t`` so the
                     compiler re-derives the catalog per epoch)
  Zipf popularity    a small hot set of chunk ids takes most of the tasks
  lognormal sizes    mean-1 per-task service-size multipliers

Timestamps come from inverse-CDF sampling of the integrated intensity on a
fine grid — fully vectorized, deterministic in ``seed``.  ``production_day``
is the canonical parameterization the registry scenario and benchmarks use
(cached per (n_tasks, seed): it is re-realized by every canonical-pad
sweep)."""
from __future__ import annotations

import numpy as np

from .format import ArrivalLog, ensure_valid

_GRID = 4096          # intensity-integration resolution (slots-agnostic)


def synth_trace(*, name: str = "synthetic", n_tasks: int = 50_000,
                horizon: float = 86_400.0, seed: int = 0,
                diurnal_amp: float = 0.3, diurnal_cycles: float = 1.0,
                n_flash: int = 2, flash_peak: float = 3.0,
                flash_frac: float = 0.02, n_chunks: int = 512,
                zipf_s: float = 1.1, churn_t: tuple = (),
                size_sigma: float = 0.35,
                n_tenants: int = 0) -> ArrivalLog:
    """One synthetic trace; see module docstring for the ingredient model.

    flash episodes each last ``flash_frac`` of the horizon at ``flash_peak``
    times the base intensity; ``churn_t`` boundaries reshuffle which chunk
    ids are hot (an independent popularity-rank permutation per epoch).
    Deterministic in ``seed``."""
    if n_tasks <= 0:
        raise ValueError("n_tasks must be > 0")
    rng = np.random.default_rng(seed)

    # -- intensity profile on a fine grid -> inverse-CDF timestamps --------
    x = (np.arange(_GRID) + 0.5) / _GRID
    lam = 1.0 + diurnal_amp * np.sin(2.0 * np.pi * diurnal_cycles * x
                                     - 0.5 * np.pi)
    for _ in range(n_flash):
        f0 = rng.uniform(0.05, 0.95 - flash_frac)
        lam = np.where((x >= f0) & (x < f0 + flash_frac),
                       lam * flash_peak, lam)
    lam = np.maximum(lam, 0.02)
    cdf = np.concatenate([[0.0], np.cumsum(lam)])
    cdf /= cdf[-1]
    u = np.sort(rng.random(n_tasks))
    t = np.interp(u, cdf, np.arange(_GRID + 1) / _GRID) * horizon
    t = np.minimum(t, np.nextafter(horizon, 0.0))

    # -- Zipf chunk popularity, rank->id permuted per churn epoch ----------
    pop = np.arange(1, n_chunks + 1, dtype=np.float64) ** (-zipf_s)
    pop /= pop.sum()
    ranks = rng.choice(n_chunks, size=n_tasks, p=pop)
    bounds = np.asarray((0.0, *churn_t, 1.0)) * horizon
    epoch = np.clip(np.searchsorted(bounds, t, side="right") - 1,
                    0, len(churn_t))
    chunk = np.empty(n_tasks, np.int64)
    for e in range(len(churn_t) + 1):
        perm = rng.permutation(n_chunks)
        m = epoch == e
        chunk[m] = perm[ranks[m]]

    # -- mean-1 lognormal sizes, optional tenants --------------------------
    z = rng.standard_normal(n_tasks)
    size = np.exp(size_sigma * z - 0.5 * size_sigma ** 2).astype(np.float32)
    tenant = None
    if n_tenants > 0:
        tp = np.arange(1, n_tenants + 1, dtype=np.float64) ** -1.0
        tenant = rng.choice(n_tenants, size=n_tasks,
                            p=tp / tp.sum()).astype(np.int32)

    return ensure_valid(ArrivalLog(
        name=name, horizon=float(horizon), t=t, chunk=chunk, size=size,
        tenant=tenant, churn_t=tuple(float(c) for c in churn_t)))


# -- the canonical production day -------------------------------------------

PRODUCTION_DAY_SEED = 7
_PRODUCTION_CACHE: dict = {}


def production_day(n_tasks: int = 120_000,
                   seed: int = PRODUCTION_DAY_SEED) -> ArrivalLog:
    """The canonical "production day": diurnal base, two flash crowds, two
    placement-churn episodes, Zipf(1.1) popularity over 512 chunks,
    lognormal(0.35) sizes, 8 tenants.  Cached per (n_tasks, seed) — the
    registry scenario realizes it on every canonical-pad sweep."""
    key = (int(n_tasks), int(seed))
    if key not in _PRODUCTION_CACHE:
        _PRODUCTION_CACHE[key] = synth_trace(
            name="production_day", n_tasks=n_tasks, seed=seed,
            diurnal_amp=0.3, diurnal_cycles=1.0,
            n_flash=2, flash_peak=3.0, flash_frac=0.02,
            n_chunks=512, zipf_s=1.1, churn_t=(0.45, 0.8),
            size_sigma=0.35, n_tenants=8)
    return _PRODUCTION_CACHE[key]


def main(argv=None) -> None:
    """CLI: synthesize a production-day trace and write it to disk.

    python -m repro.trace.synth --out day.jsonl [--n-tasks N] [--seed S]
    The encoding follows the extension (.jsonl or .npz); CI's
    trace-replay-smoke leg uses this to produce the artifact it then
    schema-validates and replays."""
    import argparse

    from .format import write_jsonl, write_npz

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--out", required=True,
                    help="output path (.jsonl or .npz)")
    ap.add_argument("--n-tasks", type=int, default=5_000)
    ap.add_argument("--seed", type=int, default=PRODUCTION_DAY_SEED)
    args = ap.parse_args(argv)
    log = production_day(n_tasks=args.n_tasks, seed=args.seed)
    if args.out.endswith(".npz"):
        write_npz(log, args.out)
    elif args.out.endswith(".jsonl"):
        write_jsonl(log, args.out)
    else:
        raise SystemExit(f"--out must end in .jsonl or .npz: {args.out}")
    print(f"[synth] wrote {log.n_tasks}-task production-day trace "
          f"({log.n_epochs} placement epochs) -> {args.out}")


if __name__ == "__main__":
    main()
