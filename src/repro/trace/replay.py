"""High-throughput trace replay over the fused route_commit megakernel.

``ReplayEngine`` serves a recorded arrival log through the Balanced-Pandas
family's fused router at sustained rates well above the per-slot simulator
path.  The speed comes from moving everything that is *known before the
run* out of the slot loop:

  host prep (once)   timestamps are binned to the slot grid, every task's
                     catalog row is resolved (compile.arrival_rows), and
                     the per-slot arrival tensors ([T, A, 3] replica
                     triples + validity mask) are packed contiguously.
  chunk prep (jit)   per chunk of S slots, locality classes ([S, A, M])
                     and pod candidate lists ([S, A, C]) are computed in
                     one vectorized shot — the slot scan then runs only
                     service progress, local scheduling, the fused
                     route_commit launch, and the accumulators.  No
                     Poisson sampling, no categorical catalog draws, no
                     window-speed machinery (trace realizations are
                     window-free: the homogeneous fast path).
  double buffering   the host->device transfer of chunk c+1 is issued
                     before chunk c's computation is awaited, so H2D
                     copies overlap compute; arrival buffers are donated
                     to the chunk step, so steady-state device memory is
                     two chunks regardless of trace length.

Dynamics are the simulator's own: the chunk step reuses
``_progress_service`` / ``_bp_schedule`` / ``kernel_route_commit`` /
``_acc`` and the same per-task size law, so ``summarize`` yields a
SimResult directly comparable to ``simulate`` on the trace-lowered
scenario (tests/test_trace.py holds mean delay within 5%).  The chunk
step compiles once per engine — ``replay_trace_count`` mirrors the
simulator's one-compile instrumentation."""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cluster import Cluster, Rates, locality_class, safe_inv_rates
from ..core.simulator import (
    BPState,
    RawSums,
    SimConfig,
    SimResult,
    _acc,
    _bp_schedule,
    _bp_workload,
    _pod_for,
    _progress_service,
    summarize,
)
from ..core.policies import PodSpec, pod_candidates
from ..kernels import route_commit as kernel_route_commit
from ..telemetry import collectors as tlm
from ..scenarios.build import realize
from .compile import arrival_rows, scenario_from_trace
from .format import ArrivalLog, ensure_valid

_REPLAY_TRACE_COUNTS: dict = {"chunk": 0}


def replay_trace_count() -> int:
    """Times the jit'd replay chunk step has been (re)traced."""
    return _REPLAY_TRACE_COUNTS["chunk"]


def reset_replay_trace_count() -> None:
    """Zero the replay-chunk retrace counter (test isolation helper)."""
    _REPLAY_TRACE_COUNTS["chunk"] = 0


class _SizeLaw(NamedTuple):
    """Duck-types ScenarioData for simulator._task_work (size fields only)."""

    size_mu: jnp.ndarray
    size_sigma: jnp.ndarray


class ReplayResult(NamedTuple):
    """One replay run: summary stats + sustained routing throughput."""
    result: SimResult               # summarize() over the replayed run
    sums: RawSums
    telemetry: Optional[object]     # Telemetry pytree (None if off)
    routed_tasks: int               # total trace arrivals routed
    wall_s: float
    tasks_per_s: float              # routed_tasks / wall_s (sustained)
    trace_count: int                # chunk-step traces during this run


@functools.partial(
    jax.jit,
    static_argnames=("cluster", "rates", "cfg", "pod", "full_bp", "tcfg",
                     "t_pad"),
    donate_argnames=("locals_c", "mask_c"))
def _replay_chunk(state: BPState, sums: RawSums, tele, locals_c, mask_c,
                  t0, sizes: _SizeLaw, key, *, cluster: Cluster,
                  rates: Rates, cfg: SimConfig, pod: Optional[PodSpec],
                  full_bp: bool, tcfg, t_pad: int):
    """Advance the replay by one chunk of S slots.

    locals_c: int32 [S, A, 3] replica triples; mask_c: bool [S, A] arrival
    validity (both donated — freed after the chunk).  t0: first global
    slot of the chunk (traced scalar: chunks share one compile)."""
    _REPLAY_TRACE_COUNTS["chunk"] += 1
    S, A = mask_c.shape
    inv_rates = safe_inv_rates(rates.as_array())
    half2_from = cfg.warmup + (cfg.T - cfg.warmup) // 2

    # vectorized chunk prep: everything per-arrival that does not depend
    # on queue state happens once, outside the slot scan
    cls_c = locality_class(cluster, locals_c)              # [S, A, M]
    if not full_bp:
        k_cand, key = jax.random.split(key)
        ci, cc, cv = pod_candidates(k_cand, cluster, locals_c, cls_c, pod)
        cv = cv & mask_c[..., None]

    def slot_step(carry, s):
        state, sums, tele = carry
        t = t0 + s
        k = jax.random.fold_in(key, s)
        k_sched, k_tie = jax.random.split(k)
        measure = (t >= cfg.warmup) & (t < cfg.T)
        busy, rem, completed = _progress_service(
            state.busy, state.rem, None, state.cls, homo=True)
        Q, busy, rem, cls_serv, starts, n_started, _pick, _start = \
            _bp_schedule(k_sched, state.Q, busy, rem, state.cls, rates,
                         cfg.service_dist, cfg.sigma, servable=None,
                         scen=sizes)
        mask_t = mask_c[s]
        if full_bp:
            Q, _W, sel, sel_cls, _val = kernel_route_commit(
                Q, mask_t, inv_rates, cls=cls_c[s],
                prio=jax.random.permutation(k_tie, cluster.M))
        else:
            Q, _W, sel, sel_cls, _val = kernel_route_commit(
                Q, mask_t, inv_rates, cand_idx=ci[s], cand_cls=cc[s],
                cand_valid=cv[s])
        routed = (jax.nn.one_hot(sel_cls, 3, dtype=jnp.float32)
                  * mask_t[:, None].astype(jnp.float32)).sum(axis=0)
        N = Q.sum().astype(jnp.float32) + busy.sum().astype(jnp.float32)
        sums = _acc(sums, in_half2=(t >= half2_from), N=N,
                    arr=mask_t.sum().astype(jnp.float32),
                    clipped=jnp.float32(0.0),   # replay never clips
                    comp=completed.sum().astype(jnp.float32),
                    starts=starts, routed=routed,
                    busy_n=busy.sum().astype(jnp.float32),
                    routes=mask_t.sum().astype(jnp.float32),
                    scheds=n_started, measure=measure)
        if tcfg is not None:
            tele = tlm.collect_step(
                tele, tcfg, t=t, T=t_pad, N=N, q_mass=Q.sum(axis=0),
                qlen=Q.sum(axis=1), workload=_bp_workload(Q, inv_rates),
                arrivals=mask_t.sum(), clipped=jnp.float32(0.0),
                completions=completed.sum(), busy_n=busy.sum(),
                probe=tlm.ZERO_PROBE)
        return (BPState(Q, busy, rem, cls_serv), sums, tele), None

    (state, sums, tele), _ = jax.lax.scan(
        slot_step, (state, sums, tele), jnp.arange(S))
    return state, sums, tele


class ReplayEngine:
    """Replay an ArrivalLog through the fused router (see module docstring).

    algo: "balanced_pandas" (full O(M) routing) or "balanced_pandas_pod"
    (power-of-d candidate routing) — the BP family the fused kernel
    serves.  cfg.T sets the slot grid the trace is binned into;
    cfg.route_mode is ignored (replay is always the fused batched path).
    telemetry: a TelemetryConfig for per-window collection (sojourn rings
    and probe replay are forced off — they are per-slot-cost features the
    replay path exists to avoid)."""

    def __init__(self, log: ArrivalLog, cluster: Cluster, rates: Rates,
                 *, cfg: SimConfig = SimConfig(),
                 algo: str = "balanced_pandas_pod",
                 pod: Optional[PodSpec] = None, chunk_slots: int = 500,
                 chunks_per_server: int = 4,
                 telemetry: Optional[tlm.TelemetryConfig] = None):
        if algo not in ("balanced_pandas", "balanced_pandas_pod"):
            raise ValueError(f"replay serves the BP family, not {algo!r}")
        self.log = ensure_valid(log)
        self.cluster, self.rates, self.cfg = cluster, rates, cfg
        self.algo = algo
        self.pod = _pod_for(algo, pod)
        self.chunk_slots = int(chunk_slots)
        self.tcfg = (dataclasses.replace(telemetry, sojourns=False,
                                         probes=False)
                     if telemetry is not None else None)

        # -- lower + realize (unpadded: window-free == homogeneous path) --
        self.scenario = scenario_from_trace(
            log, name=f"replay:{log.name}",
            chunks_per_server=chunks_per_server)
        self.scen, self.lam_cap = realize(self.scenario, cluster, rates,
                                          cfg.T)
        self.load = float(log.n_tasks / (cfg.T * self.lam_cap))
        self._sizes = _SizeLaw(self.scen.size_mu, self.scen.size_sigma)

        # -- host prep: pack per-slot arrival tensors ---------------------
        T = cfg.T
        rows = arrival_rows(log, cluster.M
                            * self.scenario.placement.chunks_per_server)
        triples = np.asarray(self.scen.chunk_locals)[rows]     # [N, 3]
        slots = log.slot_of(T)
        counts = np.bincount(slots, minlength=T)
        self.a_cap = int(max(counts.max(), 1))
        offsets = np.concatenate([[0], np.cumsum(counts)])
        within = np.arange(log.n_tasks) - offsets[slots]
        S = self.chunk_slots
        self.n_chunks = -(-T // S)
        t_pad = self.n_chunks * S
        locals_pad = np.zeros((t_pad, self.a_cap, cluster.n_replicas),
                              np.int32)
        locals_pad[:, :, :] = np.arange(cluster.n_replicas, dtype=np.int32)
        mask_pad = np.zeros((t_pad, self.a_cap), bool)
        locals_pad[slots, within] = triples
        mask_pad[slots, within] = True
        self._t_pad = t_pad
        self._chunks = [(locals_pad[c * S:(c + 1) * S],
                         mask_pad[c * S:(c + 1) * S])
                        for c in range(self.n_chunks)]

    def _step_kwargs(self) -> dict:
        return dict(cluster=self.cluster, rates=self.rates, cfg=self.cfg,
                    pod=self.pod, full_bp=(self.algo == "balanced_pandas"),
                    tcfg=self.tcfg, t_pad=self._t_pad)

    def run(self, seed: int = 0) -> ReplayResult:
        """One full replay pass; wall time covers transfer + compute (the
        sustained rate), not compilation — call ``benchmark`` for the
        warm-compile protocol."""
        key = jax.random.PRNGKey(seed)
        state = BPState.zero(self.cluster.M)
        sums = RawSums.zero()
        tele = (tlm.zero_telemetry(self.tcfg, self.cluster.M, "bp")
                if self.tcfg is not None else None)
        kw = self._step_kwargs()
        traces0 = replay_trace_count()
        put = lambda c: (jax.device_put(self._chunks[c][0]),
                         jax.device_put(self._chunks[c][1]))
        t_start = time.perf_counter()
        nxt = put(0)
        with warnings.catch_warnings():
            # backends without donation support (CPU interpret runs) warn
            # once per compile that the donated arrival buffers went unused
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            for c in range(self.n_chunks):
                cur = nxt
                if c + 1 < self.n_chunks:
                    nxt = put(c + 1)  # H2D for c+1 overlaps chunk c compute
                state, sums, tele = _replay_chunk(
                    state, sums, tele, cur[0], cur[1],
                    jnp.int32(c * self.chunk_slots), self._sizes,
                    jax.random.fold_in(key, c), **kw)
        jax.block_until_ready(sums)
        wall = time.perf_counter() - t_start
        n = self.log.n_tasks
        return ReplayResult(
            result=summarize(sums, self.algo, self.cluster, self.rates,
                             self.pod),
            sums=sums, telemetry=tele, routed_tasks=n, wall_s=wall,
            tasks_per_s=n / max(wall, 1e-9),
            trace_count=replay_trace_count() - traces0)

    def benchmark(self, seed: int = 0) -> ReplayResult:
        """Compile-and-warm pass, then a timed pass (router_bench protocol);
        returns the timed pass's result."""
        self.run(seed)
        return self.run(seed)

    def telemetry_events(self, res: ReplayResult, **manifest_extra) -> list:
        """Flatten a replay's telemetry into schema-v1 JSONL events."""
        from ..telemetry import export
        if res.telemetry is None:
            raise ValueError("engine was built without telemetry")
        manifest = export.run_manifest(
            kind="trace_replay", trace=self.log.name, algo=self.algo,
            M=self.cluster.M, K=self.cluster.K, T=self.cfg.T,
            warmup=self.cfg.warmup, load=self.load,
            tasks=res.routed_tasks, wall_s=res.wall_s,
            tasks_per_s=res.tasks_per_s, trace_count=res.trace_count,
            **manifest_extra)
        return export.to_events(res.telemetry, self.tcfg, T=self._t_pad,
                                warmup=self.cfg.warmup, manifest=manifest)
