"""Model assembler: builds every assigned architecture family from the layer
library, with scan-over-layers stacking (bounded HLO / compile time — a hard
requirement at 512 fake devices on this container and good practice at
1000-node scale), optional per-layer remat, and decode caches.

Public surface:
  init_params(cfg, key)          -> params pytree
  param_pspecs(cfg)              -> same-structure PartitionSpec pytree
  forward(params, cfg, batch)    -> (final hidden [B,S,D], aux dict)
  init_cache(cfg, B, S)          -> cache pytree (+ cache_pspecs(cfg))
  decode_step(params, cfg, cache, tokens, pos) -> (hidden [B,1,D], cache')

``batch`` is a dict: tokens [B,S] int32 always; "img_embeds" [B,Nimg,D] for
vlm; "enc_embeds" [B,S,D] for encdec (stub frontends per the assignment).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import rwkv as rk
from . import ssm
from .layers import (
    F32,
    attention_decode,
    attention_fwd,
    attention_params,
    attention_pspecs,
    dtype_of,
    embed_lookup,
    embed_params,
    embed_pspecs,
    mlp,
    mlp_params,
    mlp_pspecs,
    rmsnorm,
    rmsnorm_params,
    rmsnorm_pspecs,
)
from .moe import moe_apply, moe_params, moe_pspecs
from .sharding import constrain, logical_pspec as LP


def _stack(fn, key, n: int):
    """vmap an init over n layer keys -> stacked [n, ...] leaves."""
    return jax.vmap(fn)(jax.random.split(key, n))


def _stack_pspecs(tree):
    """Prepend the (unsharded) layer-stack dim to every PartitionSpec."""
    return jax.tree.map(lambda p: P(None, *p),
                        tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# per-family layer parameter builders
# ---------------------------------------------------------------------------


def _decoder_layer_params(key, cfg, moe: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
        "attn": attention_params(k1, cfg),
        "ln2": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
    }
    if moe:
        p["moe"] = moe_params(k2, cfg)
    else:
        p["mlp"] = mlp_params(k2, cfg.d_model, cfg.d_ff, dtype_of(cfg))
    return p


def _decoder_layer_pspecs(cfg, moe: bool):
    p = {"ln1": rmsnorm_pspecs(), "attn": attention_pspecs(),
         "ln2": rmsnorm_pspecs()}
    if moe:
        p["moe"] = moe_pspecs(cfg)
    else:
        p["mlp"] = mlp_pspecs()
    return p


def _encdec_layer_params(key, cfg, cross: bool):
    ks = jax.random.split(key, 3)
    p = {
        "ln1": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
        "attn": attention_params(ks[0], cfg),
        "ln3": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
        "mlp": mlp_params(ks[1], cfg.d_model, cfg.d_ff, dtype_of(cfg)),
    }
    if cross:
        p["ln2"] = rmsnorm_params(cfg.d_model, dtype_of(cfg))
        p["xattn"] = attention_params(ks[2], cfg)
    return p


def _encdec_layer_pspecs(cfg, cross: bool):
    p = {"ln1": rmsnorm_pspecs(), "attn": attention_pspecs(),
         "ln3": rmsnorm_pspecs(), "mlp": mlp_pspecs()}
    if cross:
        p["ln2"] = rmsnorm_pspecs()
        p["xattn"] = attention_pspecs()
    return p


def _rwkv_layer_params(key, cfg):
    return {"ln1": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
            "ln2": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
            "mix": rk.rwkv6_params(key, cfg)}


def _hybrid_group_params(key, cfg):
    """attn_every stacked mamba layers (one scan group)."""
    def one(k):
        return {"ln": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
                "mamba": ssm.mamba2_params(k, cfg)}
    return _stack(one, key, cfg.attn_every)


def init_params(cfg, key) -> dict:
    ke, kl, ks_ = jax.random.split(key, 3)
    params = {"embed": embed_params(ke, cfg),
              "final_ln": rmsnorm_params(cfg.d_model, dtype_of(cfg))}
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        params["layers"] = _stack(
            lambda k: _decoder_layer_params(k, cfg, fam == "moe"),
            kl, cfg.n_layers)
    elif fam == "encdec":
        k1, k2 = jax.random.split(kl)
        params["enc_layers"] = _stack(
            lambda k: _encdec_layer_params(k, cfg, cross=False),
            k1, cfg.n_enc_layers)
        params["dec_layers"] = _stack(
            lambda k: _encdec_layer_params(k, cfg, cross=True),
            k2, cfg.n_layers)
        params["enc_ln"] = rmsnorm_params(cfg.d_model, dtype_of(cfg))
    elif fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        params["groups"] = _stack(
            lambda k: _hybrid_group_params(k, cfg), kl, n_groups)
        kls = jax.random.split(ks_, 3)
        params["shared"] = {
            "ln1": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
            "attn": attention_params(kls[0], cfg),
            "ln2": rmsnorm_params(cfg.d_model, dtype_of(cfg)),
            "mlp": mlp_params(kls[1], cfg.d_model, cfg.d_ff, dtype_of(cfg)),
        }
    elif fam == "ssm":
        params["layers"] = _stack(lambda k: _rwkv_layer_params(k, cfg),
                                  kl, cfg.n_layers)
    else:
        raise ValueError(fam)
    return params


def param_pspecs(cfg) -> dict:
    specs = {"embed": embed_pspecs(cfg), "final_ln": rmsnorm_pspecs()}
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        specs["layers"] = _stack_pspecs(_decoder_layer_pspecs(cfg, fam == "moe"))
    elif fam == "encdec":
        specs["enc_layers"] = _stack_pspecs(_encdec_layer_pspecs(cfg, False))
        specs["dec_layers"] = _stack_pspecs(_encdec_layer_pspecs(cfg, True))
        specs["enc_ln"] = rmsnorm_pspecs()
    elif fam == "hybrid":
        inner = {"ln": rmsnorm_pspecs(), "mamba": ssm.mamba2_pspecs(cfg)}
        specs["groups"] = _stack_pspecs(_stack_pspecs(inner))
        specs["shared"] = {"ln1": rmsnorm_pspecs(), "attn": attention_pspecs(),
                           "ln2": rmsnorm_pspecs(), "mlp": mlp_pspecs()}
    elif fam == "ssm":
        specs["layers"] = _stack_pspecs(
            {"ln1": rmsnorm_pspecs(), "ln2": rmsnorm_pspecs(),
             "mix": rk.rwkv6_pspecs()})
    return specs


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def forward(params: dict, cfg, batch: dict, *, dispatch_groups: int = 1,
            collect_state: bool = False):
    """Returns (hidden [B, S, D], aux).  aux holds MoE losses and (when
    collect_state) the per-layer states serving needs for prefill->decode."""
    fam = cfg.family
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens)
    B = x.shape[0]
    aux = {"lb_loss": jnp.zeros((), F32), "z_loss": jnp.zeros((), F32)}

    if fam == "vlm":
        img = batch["img_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "batch", "seq", None)

    if fam in ("dense", "vlm", "moe"):
        def body(carry, lp):
            h, lb, zl = carry
            a = attention_fwd(lp["attn"], cfg, rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              positions, causal=True)
            h = h + a
            hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
            if fam == "moe":
                f, mx = moe_apply(lp["moe"], cfg, hn, dispatch_groups)
                lb, zl = lb + mx["lb_loss"], zl + mx["z_loss"]
            else:
                f = mlp(lp["mlp"], hn)
            return (h + f, lb, zl), None

        (x, lb, zl), _ = jax.lax.scan(_maybe_remat(body, cfg),
                                      (x, aux["lb_loss"], aux["z_loss"]),
                                      params["layers"])
        aux = {"lb_loss": lb / cfg.n_layers, "z_loss": zl / cfg.n_layers}

    elif fam == "encdec":
        enc = batch["enc_embeds"].astype(x.dtype)
        Se = enc.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))

        def enc_body(h, lp):
            a = attention_fwd(lp["attn"], cfg, rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              enc_pos, causal=False)
            h = h + a
            h = h + mlp(lp["mlp"], rmsnorm(lp["ln3"], h, cfg.norm_eps))
            return h, None

        enc, _ = jax.lax.scan(_maybe_remat(enc_body, cfg), enc,
                              params["enc_layers"])
        enc = rmsnorm(params["enc_ln"], enc, cfg.norm_eps)

        def dec_body(h, lp):
            a = attention_fwd(lp["attn"], cfg, rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              positions, causal=True)
            h = h + a
            c = attention_fwd(lp["xattn"], cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps),
                              positions, causal=False,
                              kv_override=(enc, enc_pos))
            h = h + c
            h = h + mlp(lp["mlp"], rmsnorm(lp["ln3"], h, cfg.norm_eps))
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(dec_body, cfg), x,
                            params["dec_layers"])

    elif fam == "hybrid":
        sp = params["shared"]

        def group_body(h, gp):
            def mamba_body(hh, lp):
                out = ssm.mamba2_fwd(lp["mamba"],
                                     cfg, rmsnorm(lp["ln"], hh, cfg.norm_eps))
                return hh + out, None
            h, _ = jax.lax.scan(mamba_body, h, gp)
            a = attention_fwd(sp["attn"], cfg,
                              rmsnorm(sp["ln1"], h, cfg.norm_eps),
                              positions, causal=True)
            h = h + a
            h = h + mlp(sp["mlp"], rmsnorm(sp["ln2"], h, cfg.norm_eps))
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(group_body, cfg), x, params["groups"])

    elif fam == "ssm":
        def body(h, lp):
            t = rk.rwkv6_time_mix(lp["mix"], cfg,
                                  rmsnorm(lp["ln1"], h, cfg.norm_eps))
            h = h + t
            c = rk.rwkv6_channel_mix(lp["mix"],
                                     rmsnorm(lp["ln2"], h, cfg.norm_eps))
            return h + c, None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])

    x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
    return x, aux


# ---------------------------------------------------------------------------
# decode caches + one-token decode step
# ---------------------------------------------------------------------------


class Cache(NamedTuple):
    """Family-polymorphic decode cache; unused fields are empty arrays."""
    k: jnp.ndarray            # attn KV: [L, B, S, Kv, hd]
    v: jnp.ndarray
    xk: jnp.ndarray           # encdec cross-attn K/V: [L, B, Se, Kv, hd]
    xv: jnp.ndarray
    ssm_conv: jnp.ndarray     # [L_or_groups..., B, k-1, conv_dim]
    ssm: jnp.ndarray          # [L..., B, H, N, P]
    wkv: jnp.ndarray          # [L, B, H, hd, hd]
    shift_att: jnp.ndarray    # [L, B, D]
    shift_ffn: jnp.ndarray    # [L, B, D]


def _empty():
    return jnp.zeros((0,), jnp.float32)


def init_cache(cfg, B: int, S: int) -> Cache:
    dt = dtype_of(cfg)
    hd = cfg.resolved_head_dim
    kv = cfg.padded_kv_heads
    e = _empty()
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        shp = (cfg.n_layers, B, S, kv, hd)
        return Cache(jnp.zeros(shp, dt), jnp.zeros(shp, dt), e, e, e, e, e, e, e)
    if fam == "encdec":
        shp = (cfg.n_layers, B, S, kv, hd)
        xshp = (cfg.n_layers, B, S, kv, hd)   # enc length == S cell-wise
        return Cache(jnp.zeros(shp, dt), jnp.zeros(shp, dt),
                     jnp.zeros(xshp, dt), jnp.zeros(xshp, dt), e, e, e, e, e)
    if fam == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        st = ssm.init_ssm_state(cfg, B, dt)
        conv = jnp.broadcast_to(st.conv, (ng, cfg.attn_every) + st.conv.shape)
        ssm_s = jnp.broadcast_to(st.ssm, (ng, cfg.attn_every) + st.ssm.shape)
        shp = (ng, B, S, kv, hd)
        return Cache(jnp.zeros(shp, dt), jnp.zeros(shp, dt), e, e,
                     conv, ssm_s, e, e, e)
    if fam == "ssm":
        st = rk.init_rwkv_state(cfg, B, dt)
        L = cfg.n_layers
        return Cache(e, e, e, e, e, e,
                     jnp.broadcast_to(st.wkv, (L,) + st.wkv.shape),
                     jnp.broadcast_to(st.shift_att, (L,) + st.shift_att.shape),
                     jnp.broadcast_to(st.shift_ffn, (L,) + st.shift_ffn.shape))
    raise ValueError(fam)


def cache_pspecs(cfg) -> Cache:
    e = P(None)
    kvp = P(None, *LP("batch", "cache_seq", "kv_heads", None))
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return Cache(kvp, kvp, e, e, e, e, e, e, e)
    if fam == "encdec":
        return Cache(kvp, kvp, kvp, kvp, e, e, e, e, e)
    if fam == "hybrid":
        sp = ssm.ssm_state_pspecs()
        conv = P(None, None, *sp.conv)
        ssm_p = P(None, None, *sp.ssm)
        return Cache(kvp, kvp, e, e, conv, ssm_p, e, e, e)
    if fam == "ssm":
        rp = rk.rwkv_state_pspecs()
        return Cache(e, e, e, e, e, e,
                     P(None, *rp.wkv), P(None, *rp.shift_att),
                     P(None, *rp.shift_ffn))
    raise ValueError(fam)


def decode_step(params: dict, cfg, cache: Cache, tokens: jnp.ndarray,
                pos: jnp.ndarray, dispatch_groups: int = 1):
    """One new token against a populated cache.

    tokens: [B, 1] int32; pos: [B] int32 (index of the new token).
    Returns (hidden [B, 1, D], cache').
    """
    fam = cfg.family
    x = embed_lookup(params["embed"], tokens)

    if fam in ("dense", "vlm", "moe"):
        def body(h, lpc):
            lp, ck, cv = lpc
            a, ck, cv = attention_decode(
                lp["attn"], cfg, rmsnorm(lp["ln1"], h, cfg.norm_eps),
                ck, cv, pos)
            h = h + a
            hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
            if fam == "moe":
                f, _ = moe_apply(lp["moe"], cfg, hn, dispatch_groups)
            else:
                f = mlp(lp["mlp"], hn)
            return h + f, (ck, cv)

        x, (k_new, v_new) = jax.lax.scan(body, x,
                                         (params["layers"], cache.k, cache.v))
        cache = cache._replace(k=k_new, v=v_new)

    elif fam == "encdec":
        def body(h, lpc):
            lp, ck, cv, xk, xv = lpc
            a, ck, cv = attention_decode(
                lp["attn"], cfg, rmsnorm(lp["ln1"], h, cfg.norm_eps),
                ck, cv, pos)
            h = h + a
            # cross-attn: read-only over the encoder cache
            xpos = jnp.full_like(pos, xk.shape[1] - 1)
            c, _, _ = attention_decode(
                lp["xattn"], cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps),
                xk, xv, xpos, use_rope=False, append=False)
            h = h + c
            h = h + mlp(lp["mlp"], rmsnorm(lp["ln3"], h, cfg.norm_eps))
            return h, (ck, cv)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["dec_layers"], cache.k, cache.v,
                      cache.xk, cache.xv))
        cache = cache._replace(k=k_new, v=v_new)

    elif fam == "hybrid":
        sp = params["shared"]

        def group_body(h, gpc):
            gp, conv, st, ck, cv = gpc

            def mamba_body(hh, lps):
                lp, cv_, st_ = lps
                out, ns = ssm.mamba2_decode(
                    lp["mamba"], cfg, rmsnorm(lp["ln"], hh, cfg.norm_eps),
                    ssm.SSMState(cv_, st_))
                return hh + out, (ns.conv, ns.ssm)

            h, (conv, st) = jax.lax.scan(mamba_body, h, (gp, conv, st))
            a, ck, cv = attention_decode(
                sp["attn"], cfg, rmsnorm(sp["ln1"], h, cfg.norm_eps),
                ck, cv, pos)
            h = h + a
            h = h + mlp(sp["mlp"], rmsnorm(sp["ln2"], h, cfg.norm_eps))
            return h, (conv, st, ck, cv)

        x, (conv, st, k_new, v_new) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache.ssm_conv, cache.ssm, cache.k, cache.v))
        cache = cache._replace(ssm_conv=conv, ssm=st, k=k_new, v=v_new)

    elif fam == "ssm":
        def body(h, lpc):
            lp, wkv, sa, sf = lpc
            st = rk.RWKVState(wkv, sa, sf)
            t, st = rk.rwkv6_time_mix_decode(
                lp["mix"], cfg, rmsnorm(lp["ln1"], h, cfg.norm_eps), st)
            h = h + t
            hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
            c, sf = rk.rwkv6_channel_mix(lp["mix"], hn, prev=st.shift_ffn,
                                         return_shift=True)
            return h + c, (st.wkv, st.shift_att, sf)

        x, (wkv, sa, sf) = jax.lax.scan(
            body, x, (params["layers"], cache.wkv, cache.shift_att,
                      cache.shift_ffn))
        cache = cache._replace(wkv=wkv, shift_att=sa, shift_ffn=sf)
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
    return x, cache
