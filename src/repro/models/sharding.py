"""Logical-axis sharding (MaxText-style) for the model zoo.

Every tensor dimension is named with a *logical* axis; a rules table maps
logical axes to mesh axes.  Hillclimbing a sharding (EXPERIMENTS.md §Perf)
means editing one rules entry, not touching model code.

Baseline rules (single-pod mesh ("data", "model"); the multi-pod mesh adds a
leading "pod" axis folded into the batch/fsdp axes):

  batch      -> (pod,) data      activations' batch dim (DP)
  heads/ff/vocab/expert -> model tensor parallelism / expert parallelism
  fsdp       -> data on *param* dims when cfg.fsdp (ZeRO-3: params+opt
                sharded over the data axis, re-gathered per layer inside the
                layer scan)
  cache_seq  -> data              decode KV/state caches sharded over sequence
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None)
BASE_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    # decode caches: sharded over data only in cells whose batch cannot use
    # the data axis (long_500k, global_batch=1) — see launch/dryrun.py rules.
    "cache_seq": None,
    "embed": None,
    "embed_fsdp": None,          # switched to ("pod", "data") under ZeRO/FSDP
    "heads": "model",
    "heads_flat": "model",       # flattened (H*hd) projections (rwkv)
    "kv_heads": None,
    "head_dim": None,
    "group": None,
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "moe_ff": None,      # expert FF dim; decode shards it over data (EP^2)
    "capacity": None,
    "layers": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "conv_k": None,
}

_local = threading.local()


def set_rules(overrides: Optional[dict] = None, *, mesh_axes: tuple = ("data", "model")):
    """Install the active rules table, dropping mesh axes that do not exist
    on the current mesh (e.g. "pod" on the single-pod mesh)."""
    rules = dict(BASE_RULES)
    if overrides:
        rules.update(overrides)
    resolved = {}
    for k, v in rules.items():
        if v is None:
            resolved[k] = None
        elif isinstance(v, tuple):
            kept = tuple(a for a in v if a in mesh_axes)
            resolved[k] = kept if kept else None
        else:
            resolved[k] = v if v in mesh_axes else None
    _local.rules = resolved
    return resolved


def get_rules() -> dict:
    if not hasattr(_local, "rules"):
        set_rules()
    return _local.rules


def logical_pspec(*names: Optional[str]) -> P:
    """PartitionSpec for a tensor whose dims carry these logical names.

    A mesh axis may appear on at most one tensor dim; if two logical names
    resolve to the same mesh axis, the first dim wins and later dims drop it.
    """
    rules = get_rules()
    used: set = set()
    out = []
    for n in names:
        v = rules.get(n) if n is not None else None
        axes = v if isinstance(v, tuple) else (v,) if v is not None else ()
        kept = tuple(a for a in axes if a not in used)
        used.update(kept)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names (no-op outside a mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, logical_pspec(*names))
    except Exception:
        return x  # no mesh active (unit tests on a single device)
