"""RWKV-6 "Finch" block: data-dependent-decay linear attention (time-mix)
plus squared-ReLU channel-mix.

Chunked time-mix: within a chunk, the pairwise decay
exp(cum_t - logw_t - cum_s) is always <= 1 for s < t (cum is a running sum
of logw <= 0), so the [Lc, Lc, hd] decay tensor is numerically safe in f32;
across chunks a scan carries the per-head [hd, hd] state.  Decode is a pure
O(1) state update — this is why rwkv6-7b runs the long_500k cell.

Simplification vs the published block (DESIGN.md §5): the token-shift mixing
coefficients are static learned vectors (the paper adds a data-dependent
LoRA on all five); the decay w keeps its full data-dependent LoRA, which is
the part that defines RWKV-6.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import F32, dense_init, dtype_of
from .sharding import constrain, logical_pspec as LP

_LORA = 64


class RWKVState(NamedTuple):
    wkv: jnp.ndarray        # [B, H, hd, hd] per-head state (f32)
    shift_att: jnp.ndarray  # [B, D] previous token (time-mix)
    shift_ffn: jnp.ndarray  # [B, D] previous token (channel-mix)


def rwkv6_params(key, cfg) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    H = cfg.n_heads
    hd = cfg.d_model // H
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 12)
    mu = lambda k: jax.random.uniform(k, (d,), F32, 0.0, 1.0).astype(dt)
    return {
        "mu_r": mu(ks[0]), "mu_k": mu(ks[1]), "mu_v": mu(ks[2]),
        "mu_w": mu(ks[3]), "mu_g": mu(ks[4]),
        "wr": dense_init(ks[5], d, (d, d), dt),
        "wk": dense_init(ks[6], d, (d, d), dt),
        "wv": dense_init(ks[7], d, (d, d), dt),
        "wg": dense_init(ks[8], d, (d, d), dt),
        "wo": dense_init(ks[9], d, (d, d), dt),
        "w0": jnp.full((d,), -0.6, F32),
        "wA": dense_init(ks[10], d, (d, _LORA), dt),
        "wB": dense_init(ks[11], _LORA, (_LORA, d), dt),
        "u": jnp.zeros((H, hd), F32),
        "ln_scale": jnp.ones((d,), F32),      # per-head group norm
        # channel mix
        "cm_mu_k": mu(ks[0]), "cm_mu_r": mu(ks[1]),
        "cm_wk": dense_init(ks[2], d, (d, dff), dt),
        "cm_wv": dense_init(ks[3], dff, (dff, d), dt),
        "cm_wr": dense_init(ks[4], d, (d, d), dt),
    }


def rwkv6_pspecs() -> dict:
    return {
        "mu_r": LP(None), "mu_k": LP(None), "mu_v": LP(None),
        "mu_w": LP(None), "mu_g": LP(None),
        "wr": LP("embed_fsdp", "heads_flat"), "wk": LP("embed_fsdp", "heads_flat"),
        "wv": LP("embed_fsdp", "heads_flat"), "wg": LP("embed_fsdp", "heads_flat"),
        "wo": LP("heads_flat", "embed_fsdp"),
        "w0": LP("heads_flat"), "wA": LP("embed_fsdp", None),
        "wB": LP(None, "heads_flat"),
        "u": LP("heads", None), "ln_scale": LP("heads_flat"),
        "cm_mu_k": LP(None), "cm_mu_r": LP(None),
        "cm_wk": LP("embed_fsdp", "ff"), "cm_wv": LP("ff", "embed_fsdp"),
        "cm_wr": LP("embed_fsdp", "heads_flat"),
    }


def _shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """xx[t] = x[t-1]; position 0 takes ``prev`` (decode carry) or zeros."""
    first = (prev[:, None, :] if prev is not None
             else jnp.zeros_like(x[:, :1]))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _headnorm(y: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """GroupNorm with one group per head.  y: [B, S, H, hd]."""
    yf = y.astype(F32)
    mean = yf.mean(axis=-1, keepdims=True)
    var = yf.var(axis=-1, keepdims=True)
    n = (yf - mean) * jax.lax.rsqrt(var + eps)
    B, S, H, hd = y.shape
    return (n.reshape(B, S, H * hd) * scale).astype(y.dtype)


def rwkv6_time_mix(p: dict, cfg, x: jnp.ndarray, *, chunk: int = 64,
                   state: Optional[RWKVState] = None,
                   return_state: bool = False):
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    Lc = min(chunk, S)
    assert S % Lc == 0
    nc = S // Lc

    xx = _shift(x, state.shift_att if state is not None else None)
    mix = lambda mu: x + (xx - x) * mu[None, None, :].astype(x.dtype)
    r = jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["wr"])
    k = jnp.einsum("bsd,de->bse", mix(p["mu_k"]), p["wk"])
    v = jnp.einsum("bsd,de->bse", mix(p["mu_v"]), p["wv"])
    g = jnp.einsum("bsd,de->bse", mix(p["mu_g"]), p["wg"])
    lora = jnp.einsum("bsl,ld->bsd",
                      jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(p["mu_w"]), p["wA"])),
                      p["wB"])
    logw = -jnp.exp(jnp.clip(p["w0"] + lora.astype(F32), -8.0, 2.0))  # <= 0

    shp = (B, nc, Lc, H, hd)
    r_c = r.reshape(shp).astype(F32)
    k_c = k.reshape(shp).astype(F32)
    v_c = v.reshape(shp).astype(F32)
    lw = logw.reshape(shp)
    cum = jnp.cumsum(lw, axis=2)                      # [B,nc,Lc,H,hd]

    s0 = (state.wkv if state is not None
          else jnp.zeros((B, H, hd, hd), F32))

    def one_chunk(s_prev, inp):
        rr, kk, vv, cc, ww = inp                      # [B,Lc,H,hd] each
        # intra-chunk strict-lower scores (all decay factors <= 1)
        dec = jnp.exp(cc[:, :, None] - ww[:, :, None] - cc[:, None, :])
        tri = jnp.tril(jnp.ones((Lc, Lc), bool), k=-1)
        dec = jnp.where(tri[None, :, :, None, None], dec, 0.0)
        scores = jnp.einsum("bthd,btshd,bshd->btsh", rr, dec, kk)
        y = jnp.einsum("btsh,bshp->bthp", scores, vv)
        # diagonal bonus term
        y = y + jnp.einsum("bthd,hd,bthd,bthp->bthp", rr, p["u"], kk, vv)
        # inter-chunk from carried state
        rdec = rr * jnp.exp(cc - ww)
        y = y + jnp.einsum("bthd,bhdp->bthp", rdec, s_prev)
        # state update (all factors <= 1)
        last = cc[:, -1:, :, :]
        kdec = kk * jnp.exp(last - cc)
        s_new = s_prev * jnp.exp(last[:, 0])[..., None] + \
            jnp.einsum("bthd,bthp->bhdp", kdec, vv)
        return s_new, y

    xs = tuple(a.transpose(1, 0, 2, 3, 4) for a in (r_c, k_c, v_c, cum, lw))
    s_final, y = jax.lax.scan(one_chunk, s0, xs)
    y = y.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd).astype(x.dtype)

    out = _headnorm(y, p["ln_scale"], cfg.norm_eps)
    out = out * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    if return_state:
        new_state = RWKVState(wkv=s_final, shift_att=x[:, -1, :],
                              shift_ffn=jnp.zeros_like(x[:, -1, :]))
        return out, new_state
    return out


def rwkv6_time_mix_decode(p: dict, cfg, x: jnp.ndarray, state: RWKVState):
    """One-token decode.  x: [B, 1, D]; O(1) in context."""
    B, _, D = x.shape
    H = cfg.n_heads
    hd = D // H

    xx = state.shift_att[:, None, :]
    mix = lambda mu: x + (xx - x) * mu[None, None, :].astype(x.dtype)
    r = jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["wr"])[:, 0]
    k = jnp.einsum("bsd,de->bse", mix(p["mu_k"]), p["wk"])[:, 0]
    v = jnp.einsum("bsd,de->bse", mix(p["mu_v"]), p["wv"])[:, 0]
    g = jnp.einsum("bsd,de->bse", mix(p["mu_g"]), p["wg"])[:, 0]
    lora = jnp.einsum("bl,ld->bd",
                      jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(p["mu_w"]), p["wA"])[:, 0]),
                      p["wB"])
    logw = -jnp.exp(jnp.clip(p["w0"] + lora.astype(F32), -8.0, 2.0))

    rh = r.reshape(B, H, hd).astype(F32)
    kh = k.reshape(B, H, hd).astype(F32)
    vh = v.reshape(B, H, hd).astype(F32)
    w = jnp.exp(logw).reshape(B, H, hd)

    kv = jnp.einsum("bhd,bhp->bhdp", kh, vh)
    y = jnp.einsum("bhd,bhdp->bhp", rh * p["u"][None], kv) + \
        jnp.einsum("bhd,bhdp->bhp", rh, state.wkv)
    s_new = state.wkv * w[..., None] + kv

    y = y.reshape(B, 1, H, hd).astype(x.dtype)
    out = _headnorm(y, p["ln_scale"], cfg.norm_eps)
    out = out * jax.nn.silu(g.astype(F32)).astype(x.dtype)[:, None, :]
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, state._replace(wkv=s_new, shift_att=x[:, 0, :])


def rwkv6_channel_mix(p: dict, x: jnp.ndarray,
                      prev: Optional[jnp.ndarray] = None,
                      return_shift: bool = False):
    xx = _shift(x, prev)
    mix = lambda mu: x + (xx - x) * mu[None, None, :].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", mix(p["cm_mu_k"]), p["cm_wk"])
    k = jnp.square(jax.nn.relu(k.astype(F32))).astype(x.dtype)
    k = constrain(k, "batch", "seq", "ff")
    kv = jnp.einsum("bsf,fd->bsd", k, p["cm_wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", mix(p["cm_mu_r"]),
                                  p["cm_wr"]).astype(F32)).astype(x.dtype)
    out = r * kv
    if return_shift:
        return out, x[:, -1, :]
    return out


def init_rwkv_state(cfg, B: int, dtype) -> RWKVState:
    H = cfg.n_heads
    hd = cfg.d_model // H
    return RWKVState(
        wkv=jnp.zeros((B, H, hd, hd), F32),
        shift_att=jnp.zeros((B, cfg.d_model), dtype),
        shift_ffn=jnp.zeros((B, cfg.d_model), dtype))


def rwkv_state_pspecs():
    return RWKVState(wkv=LP("batch", "heads", None, None),
                     shift_att=LP("batch", None),
                     shift_ffn=LP("batch", None))
