"""Shared transformer layers: RMSNorm, RoPE, GQA attention (flash-chunked
train/prefill path + cache-reading decode path), SwiGLU MLP, embeddings.

Conventions:
  - params are plain nested dicts of jnp arrays; every ``*_params`` init has a
    matching ``*_pspecs`` returning the same-structure PartitionSpec tree
    (logical axes; see sharding.py).
  - compute dtype is bf16 with fp32 islands (norm statistics, softmax,
    logsumexp); params are stored in cfg.dtype.
  - the train/prefill attention is flash-style (online softmax over KV
    blocks) so activation memory is O(S * block) instead of O(S^2) — the
    32k-prefill cells do not fit any other way.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .sharding import constrain, logical_pspec as LP

F32 = jnp.float32


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, F32)
            * scale).astype(dtype)


def dense_init(key, d_in: int, shape: tuple, dtype) -> jnp.ndarray:
    """Fan-in scaled truncated-normal init."""
    return _init(key, shape, d_in ** -0.5, dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_params(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_pspecs() -> dict:
    return {"scale": LP(None)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] (absolute).  Pairs (even, odd)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    ang = positions[..., None].astype(F32) * freqs          # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-chunked attention (train / prefill)
# ---------------------------------------------------------------------------


def _to_blocks(x, n, blk):
    B, S, H, hd = x.shape
    return x.reshape(B, n, blk, H, hd).transpose(1, 0, 3, 2, 4)  # [n,B,H,blk,hd]


def _fa_forward(q, k, v, causal, q_block, kv_block):
    """Returns (out [B,Sq,H,hd], lse [nq,B,H,q_block])."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // q_block, Sk // kv_block
    scale = hd ** -0.5
    qb = _to_blocks(q, nq, q_block)
    kb = _to_blocks(k, nk, kv_block)
    vb = _to_blocks(v, nk, kv_block)

    def one_q(_, qi_and_q):
        qi, qq = qi_and_q                      # qq [B, H, qb, hd]
        qq = qq.astype(F32) * scale

        def kv_step(carry, ki_and_kv):
            ki, kk, vv = ki_and_kv
            m, l, acc = carry
            s = jnp.einsum("bhqd,bhkd->bhqk", qq, kk.astype(F32))
            if causal:
                qpos = qi * q_block + jnp.arange(q_block)
                kpos = ki * kv_block + jnp.arange(kv_block)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - safe_m[..., None])
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vv.astype(F32))
            return (m_new, l, acc), None

        init = (jnp.full((B, H, q_block), -jnp.inf, F32),
                jnp.zeros((B, H, q_block), F32),
                jnp.zeros((B, H, q_block, hd), F32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = jnp.where(jnp.isfinite(m), m + jnp.log(jnp.maximum(l, 1e-30)),
                        -jnp.inf)
        return None, (out.astype(q.dtype), lse)

    _, (ob, lse) = jax.lax.scan(one_q, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, hd)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, q_block, kv_block):
    return _fa_forward(q, k, v, causal, q_block, kv_block)[0]


def _flash_fwd(q, k, v, causal, q_block, kv_block):
    out, lse = _fa_forward(q, k, v, causal, q_block, kv_block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_block, kv_block, res, do):
    """FlashAttention-2 backward: recompute p per (q, kv) block pair from
    the saved logsumexp; only O(S*hd) residuals were kept by the forward.
    Scan carries are O(block) (dkj/dvj per step) plus one dq accumulator —
    this is what keeps the 32k-train backward inside HBM (the naive scan
    backward stores the [B,H,qb,hd] accumulator per kv step)."""
    q, k, v, o, lse = res
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // q_block, Sk // kv_block
    scale = hd ** -0.5

    qb = _to_blocks(q, nq, q_block).astype(F32)          # [nq,B,H,qb,hd]
    kb = _to_blocks(k, nk, kv_block).astype(F32)
    vb = _to_blocks(v, nk, kv_block).astype(F32)
    dob = _to_blocks(do, nq, q_block).astype(F32)
    ob = _to_blocks(o, nq, q_block).astype(F32)
    Dd = jnp.sum(dob * ob, axis=-1)                      # [nq,B,H,qb]

    def kv_step(dq_full, j_kv):
        j, kk, vv = j_kv

        def q_step(carry, i_q):
            dkj, dvj, dq_acc = carry
            i, qq, doi, lsei, Di = i_q
            s = jnp.einsum("bhqd,bhkd->bhqk", qq * scale, kk)
            if causal:
                qpos = i * q_block + jnp.arange(q_block)
                kpos = j * kv_block + jnp.arange(kv_block)
                s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None],
                              s, -jnp.inf)
            safe_lse = jnp.where(jnp.isfinite(lsei), lsei, 0.0)
            p = jnp.exp(s - safe_lse[..., None])          # masked -> 0
            dp = jnp.einsum("bhqd,bhkd->bhqk", doi, vv)
            ds = p * (dp - Di[..., None]) * scale
            dqi = jnp.einsum("bhqk,bhkd->bhqd", ds, kk)
            dkj = dkj + jnp.einsum("bhqk,bhqd->bhkd", ds, qq)
            dvj = dvj + jnp.einsum("bhqk,bhqd->bhkd", p, doi)
            dq_acc = dq_acc.at[i].add(dqi)
            return (dkj, dvj, dq_acc), None

        zk = jnp.zeros((B, H, kv_block, hd), F32)
        (dkj, dvj, dq_full), _ = jax.lax.scan(
            q_step, (zk, zk, dq_full),
            (jnp.arange(nq), qb, dob, lse, Dd))
        return dq_full, (dkj, dvj)

    dq0 = jnp.zeros((nq, B, H, q_block, hd), F32)
    dq_full, (dk_b, dv_b) = jax.lax.scan(kv_step, dq0,
                                         (jnp.arange(nk), kb, vb))

    def _from_blocks(x, S):
        return x.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)

    return (_from_blocks(dq_full, Sq).astype(q.dtype),
            _from_blocks(dk_b, Sk).astype(k.dtype),
            _from_blocks(dv_b, Sk).astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool, q_block: int, kv_block: int) -> jnp.ndarray:
    """Online-softmax attention with a FlashAttention-2 style custom VJP.
    q,k,v: [B, S, H, hd] (KV already repeated to H heads).  Activation
    residency is O(S*hd) (out + logsumexp); the backward recomputes the
    probability blocks.  The causal path still *computes* masked blocks
    (2x attention-FLOPs waste in the roofline — §Perf iterates on this)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0
    return _flash(q, k, v, causal, q_block, kv_block)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def head_mask(cfg) -> Optional[jnp.ndarray]:
    """[padded_heads] 1/0 mask (None when no padding).  Padded q-heads sit at
    the tail of each kv group, so q-head i keeps kv head i // padded_groups."""
    Hp, H = cfg.padded_heads, cfg.n_heads
    if Hp == H:
        return None
    Gp, G = cfg.padded_q_groups, cfg.q_groups
    if Gp != G:      # GQA: pad within each group
        return ((jnp.arange(Hp) % Gp) < G).astype(F32)
    return (jnp.arange(Hp) < H).astype(F32)   # MHA: pad q+kv together


def attention_params(key, cfg, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    Hp, Kvp = cfg.padded_heads, cfg.padded_kv_heads
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, (d, Hp, hd), dt),
        "wk": dense_init(k2, d, (d, Kvp, hd), dt),
        "wv": dense_init(k3, d, (d, Kvp, hd), dt),
        "wo": dense_init(k4, cfg.n_heads * hd, (Hp, hd, d), dt),
    }
    mask = head_mask(cfg)
    if mask is not None:   # zero the padded heads; the fwd mask keeps them 0
        p["wq"] = p["wq"] * mask[None, :, None].astype(dt)
        p["wo"] = p["wo"] * mask[:, None, None].astype(dt)
    return p


def attention_pspecs() -> dict:
    return {
        "wq": LP("embed_fsdp", "heads", "head_dim"),
        "wk": LP("embed_fsdp", "kv_heads", "head_dim"),
        "wv": LP("embed_fsdp", "kv_heads", "head_dim"),
        "wo": LP("heads", "head_dim", "embed_fsdp"),
    }


def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """[B, S, Kv, hd] -> [B, S, Kv*groups, hd]."""
    if groups == 1:
        return x
    B, S, Kv, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (B, S, Kv, groups, hd)
                            ).reshape(B, S, Kv * groups, hd)


def attention_fwd(p: dict, cfg, x: jnp.ndarray, positions: jnp.ndarray, *,
                  causal: bool = True, use_rope: bool = True,
                  kv_override: Optional[tuple] = None) -> jnp.ndarray:
    """Train/prefill path.  x: [B, S, D] -> [B, S, D].  kv_override feeds
    cross-attention (keys/values come from the encoder stream)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        src = x
    else:
        src = kv_override[0]
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        kpos = positions if kv_override is None else kv_override[1]
        k = rope(k, kpos, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = _repeat_kv(k, cfg.padded_q_groups)
    v = _repeat_kv(v, cfg.padded_q_groups)
    k = constrain(k, "batch", "seq", "heads", None)
    o = flash_attention(q, k, v, causal=causal,
                        q_block=cfg.q_block, kv_block=cfg.kv_block)
    mask = head_mask(cfg)
    if mask is not None:
        o = o * mask[None, None, :, None].astype(o.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attention_decode(p: dict, cfg, x: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, pos: jnp.ndarray, *,
                     use_rope: bool = True, append: bool = True):
    """Decode path: x [B, 1, D]; cache_k/v [B, S, Kv, hd]; pos [B] int32.

    Grouped-query attention directly against the (sequence-sharded) cache —
    no KV repeat is materialized.  Returns (out [B,1,D], cache_k', cache_v').
    """
    B, S, Kv, hd = cache_k.shape
    G = cfg.padded_q_groups
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])          # [B,1,Hp,hd]
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])      # [B,1,Kv,hd]
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k_new = rope(k_new, pos[:, None], cfg.rope_theta)
    if append:
        onehot = (jnp.arange(S)[None, :] == pos[:, None]).astype(cache_k.dtype)
        cache_k = cache_k + onehot[:, :, None, None] * k_new.astype(cache_k.dtype)
        cache_v = cache_v + onehot[:, :, None, None] * v_new.astype(cache_v.dtype)
        cache_k = constrain(cache_k, "batch", "cache_seq", "kv_heads", None)
        cache_v = constrain(cache_v, "batch", "cache_seq", "kv_heads", None)
    qg = q.reshape(B, Kv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32),
                   cache_k.astype(F32)) * (hd ** -0.5)
    mask = (jnp.arange(S)[None, :] <= pos[:, None])[:, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    pw = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", pw, cache_v.astype(F32))
    o = o.reshape(B, 1, Kv * G, hd).astype(x.dtype)
    mask = head_mask(cfg)
    if mask is not None:
        o = o * mask[None, None, :, None].astype(o.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), cache_k, cache_v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_params(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, d, (d, d_ff), dtype),
        "w3": dense_init(k2, d, (d, d_ff), dtype),
        "w2": dense_init(k3, d_ff, (d_ff, d), dtype),
    }


def mlp_pspecs() -> dict:
    return {"w1": LP("embed_fsdp", "ff"), "w3": LP("embed_fsdp", "ff"),
            "w2": LP("ff", "embed_fsdp")}


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, p["w1"])
    u = jnp.einsum("bsd,df->bsf", x, p["w3"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    h = constrain(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------


def embed_params(key, cfg) -> dict:
    V = cfg.padded_vocab
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": _init(k1, (V, cfg.d_model), 1.0, dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, cfg.d_model, (cfg.d_model, V), dt)
    return p


def embed_pspecs(cfg) -> dict:
    p = {"tok": LP("vocab", "embed_fsdp")}
    if not cfg.tie_embeddings:
        p["head"] = LP("embed_fsdp", "vocab")
    return p


def embed_lookup(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    out = jnp.take(p["tok"], tokens, axis=0)
    return constrain(out, "batch", "seq", None)


def logits_fn(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    w = p["tok"].T if "head" not in p else p["head"]
    out = jnp.einsum("bsd,dv->bsv", x, w)
    return constrain(out, "batch", "seq", "vocab")


def chunked_softmax_xent(embed_p: dict, x: jnp.ndarray, labels: jnp.ndarray,
                         vocab: int, chunk: int = 256) -> jnp.ndarray:
    """Mean cross-entropy, computing logits seq-chunk by seq-chunk so the
    [B, S, V] tensor never materializes (V is model-sharded; the fp32
    logsumexp stays per-chunk)."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    assert S % chunk == 0
    xc = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def one(carry, xl):
        xx, ll = xl
        logits = logits_fn(embed_p, xx).astype(F32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        valid = ll < vocab                       # padded labels masked out
        return carry + jnp.sum(jnp.where(valid, lse - gold, 0.0)), None

    total, _ = jax.lax.scan(one, jnp.zeros((), F32), (xc, lc))
    return total / (B * S)
