"""Model zoo: every assigned architecture family in pure JAX."""
from .layers import chunked_softmax_xent, flash_attention, logits_fn
from .sharding import constrain, get_rules, logical_pspec, set_rules
from .transformer import (
    Cache,
    cache_pspecs,
    decode_step,
    forward,
    init_cache,
    init_params,
    param_pspecs,
)

__all__ = [
    "Cache", "cache_pspecs", "chunked_softmax_xent", "constrain",
    "decode_step", "flash_attention", "forward", "get_rules", "init_cache",
    "init_params", "logical_pspec", "logits_fn", "param_pspecs", "set_rules",
]
