"""Mixture-of-Experts FFN with group-local capacity dispatch (EP-shardable).

Dispatch is computed *per data-parallel group* (``dispatch_groups`` = number
of data shards): tokens are reshaped to [G, T_local], the top-k assignment is
sorted within each group, and tokens beyond the per-group per-expert
capacity C = ceil(T_local * k / E * capacity_factor) are dropped (GShard-
style).  Because the sort, gather and scatter all act along the *local*
token axis, GSPMD partitions them without cross-group communication; the
only collective the layer needs is the expert-parallel combine all-reduce
over the model axis — the same volume as a tensor-parallel FFN.  DESIGN.md
§4 and EXPERIMENTS.md §Roofline discuss the resulting collective terms.

Router extras (production requirements): switch load-balance auxiliary loss
and router z-loss, both returned for the trainer to weight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import F32, dense_init, dtype_of, mlp, mlp_params, mlp_pspecs
from .sharding import constrain, logical_pspec as LP


def moe_params(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, (d, e), jnp.float32),
        "w1": dense_init(ks[1], d, (e, d, f), dt),
        "w3": dense_init(ks[2], d, (e, d, f), dt),
        "w2": dense_init(ks[3], f, (e, f, d), dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(ks[4], d, cfg.n_shared_experts * cfg.moe_d_ff, dt)
    return p


def moe_pspecs(cfg) -> dict:
    p = {
        "router": LP("embed_fsdp", None),
        "w1": LP("expert", "embed_fsdp", "moe_ff"),
        "w3": LP("expert", "embed_fsdp", "moe_ff"),
        "w2": LP("expert", "moe_ff", "embed_fsdp"),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_pspecs()
    return p


def moe_apply(p: dict, cfg, x: jnp.ndarray, dispatch_groups: int = 1
              ) -> tuple[jnp.ndarray, dict]:
    """x: [B, S, D] -> ([B, S, D], aux losses {lb_loss, z_loss})."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    G = min(dispatch_groups, T)
    Tl = T // G
    assert T % G == 0, (T, G)
    C = max(8, int(-(-Tl * K * cfg.capacity_factor // E)))

    gax = "batch" if G > 1 else None   # a size-1 group dim must not claim
    xf = x.reshape(G, Tl, D)           # the data axis away from moe_ff
    xf = constrain(xf, gax, None, None)

    logits = jnp.einsum("gtd,de->gte", xf.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                 # [G, Tl, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux losses (Switch LB + z-loss)
    me = probs.mean(axis=(0, 1))                            # [E]
    ce = jnp.zeros(E, F32).at[top_e.reshape(-1)].add(
        jnp.ones(top_e.size, F32)) / (T * K)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- group-local sort-based dispatch -------------------------------
    e_flat = top_e.reshape(G, Tl * K)
    t_flat = jnp.broadcast_to(jnp.arange(Tl)[:, None], (Tl, K)).reshape(-1)
    t_flat = jnp.broadcast_to(t_flat[None], (G, Tl * K))
    w_flat = top_w.reshape(G, Tl * K)

    order = jnp.argsort(e_flat, axis=-1)
    se = jnp.take_along_axis(e_flat, order, -1)
    st = jnp.take_along_axis(t_flat, order, -1)
    sw = jnp.take_along_axis(w_flat, order, -1)
    first = jax.vmap(lambda row: jnp.searchsorted(row, row, side="left"))(se)
    pos = jnp.arange(Tl * K)[None, :] - first
    keep = pos < C
    slot = se * C + jnp.minimum(pos, C - 1)                # [G, Tl*K]

    gidx = jnp.arange(G)[:, None]
    disp = jnp.full((G, E * C), Tl, jnp.int32).at[gidx, slot].set(
        jnp.where(keep, st, Tl).astype(jnp.int32), mode="drop")

    x_pad = jnp.concatenate([xf, jnp.zeros((G, 1, D), xf.dtype)], axis=1)
    x_disp = jnp.take_along_axis(
        x_pad, disp[..., None], axis=1).reshape(G, E, C, D)
    x_disp = constrain(x_disp, gax, "expert", "capacity", None)

    g = jnp.einsum("gecd,edf->gecf", x_disp, p["w1"])
    u = jnp.einsum("gecd,edf->gecf", x_disp, p["w3"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    h = constrain(h, gax, "expert", "capacity", "moe_ff")
    y = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    y = constrain(y, gax, "expert", "capacity", None)

    # --- combine as GATHER + per-token reduction --------------------------
    # A scatter-add combine is opaque to GSPMD: it materialized the output
    # replicated ([G,Tl,D] f32 per device) and all-reduced 2 x 7.5 GB per
    # layer-microbatch on kimi-k2 (§Perf).  Inverting the sort permutation
    # turns the combine into a batched gather (token t, choice j reads its
    # expert slot) which partitions exactly like the dispatch gather.
    inv = jnp.argsort(order, axis=-1)
    slot_tok = jnp.take_along_axis(
        jnp.where(keep, slot, E * C), inv, axis=-1)          # [G, Tl*K]
    y_pad = jnp.concatenate(
        [y.reshape(G, E * C, D),
         jnp.zeros((G, 1, D), y.dtype)], axis=1)
    contrib = jnp.take_along_axis(y_pad, slot_tok[..., None], axis=1)
    out = (contrib.reshape(G, Tl, K, D).astype(F32)
           * top_w[..., None]).sum(axis=2)
    out = constrain(out.astype(x.dtype), gax, None, None)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf)
    return out.reshape(B, S, D), {"lb_loss": lb_loss, "z_loss": z_loss}
