"""Mamba2 (SSD) block — the zamba2 backbone layer.

Chunked state-space-dual algorithm (Mamba-2 paper §6): within a chunk the
output is an attention-like lower-triangular contraction with per-head
scalar decay; across chunks a scan carries the [B, H, N, P] state.  All
decay exponentials are differences of a within-chunk cumulative sum, so
every factor is <= 1 (numerically safe in f32).

Prefill returns the final (conv window, SSM state) so serving can hand off
to the O(1)-per-token decode step — the property that lets zamba2/rwkv6 run
the long_500k cell (DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import F32, dense_init, dtype_of, rmsnorm, rmsnorm_params, rmsnorm_pspecs
from .sharding import constrain, logical_pspec as LP


class SSMState(NamedTuple):
    conv: jnp.ndarray    # [B, k-1, conv_dim] rolling conv window
    ssm: jnp.ndarray     # [B, H, N, P] recurrent state


def mamba2_params(key, cfg) -> dict:
    d, di, N, H, P = (cfg.d_model, cfg.ssm_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_head_dim)
    k = cfg.ssm_conv
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], d, (d, di), dt),
        "wx": dense_init(ks[1], d, (d, di), dt),
        "wB": dense_init(ks[2], d, (d, N), dt),
        "wC": dense_init(ks[3], d, (d, N), dt),
        "wdt": dense_init(ks[4], d, (d, H), dt),
        "conv_w": dense_init(ks[5], k, (k, di + 2 * N), dt),
        "conv_b": jnp.zeros((di + 2 * N,), dt),
        "A_log": jnp.zeros((H,), F32),
        "D": jnp.ones((H,), F32),
        "dt_bias": jnp.full((H,), -2.0, F32),   # softplus(-2) ~ 0.13
        "norm": rmsnorm_params(di, dt),
        "wo": dense_init(ks[6], di, (di, d), dt),
    }


def mamba2_pspecs(cfg) -> dict:
    return {
        "wz": LP("embed_fsdp", "ssm_inner"),
        "wx": LP("embed_fsdp", "ssm_inner"),
        "wB": LP("embed_fsdp", None),
        "wC": LP("embed_fsdp", None),
        "wdt": LP("embed_fsdp", "ssm_inner"),
        "conv_w": LP(None, "ssm_inner"),
        "conv_b": LP("ssm_inner"),
        "A_log": LP("ssm_inner"),
        "D": LP("ssm_inner"),
        "dt_bias": LP("ssm_inner"),
        "norm": rmsnorm_pspecs(),
        "wo": LP("ssm_inner", "embed_fsdp"),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 window: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv.  u: [B, S, C]; w: [k, C]; window: [B, k-1, C]
    (history; zeros for a fresh sequence)."""
    k = w.shape[0]
    if window is None:
        window = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([window, u], axis=1)
    out = sum(up[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def mamba2_fwd(p: dict, cfg, x: jnp.ndarray, *, chunk: int = 128,
               state: Optional[SSMState] = None,
               return_state: bool = False):
    """x: [B, S, D] -> [B, S, D] (and final SSMState if requested)."""
    B, S, D = x.shape
    di, N, H, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Lc = min(chunk, S)
    assert S % Lc == 0
    nc = S // Lc

    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xs = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt_r = jnp.einsum("bsd,dh->bsh", x, p["wdt"])

    u = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_win = state.conv if state is not None else None
    u = jax.nn.silu(_causal_conv(u, p["conv_w"], p["conv_b"], conv_win
                                 ).astype(F32)).astype(x.dtype)
    new_conv = jnp.concatenate(
        [conv_win if conv_win is not None else
         jnp.zeros((B, cfg.ssm_conv - 1, di + 2 * N), x.dtype),
         jnp.concatenate([xs, Bm, Cm], axis=-1)], axis=1)[:, -(cfg.ssm_conv - 1):]
    xs, Bm, Cm = jnp.split(u, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt_r.astype(F32) + p["dt_bias"])      # [B, S, H]
    A = -jnp.exp(p["A_log"])                                    # [H], < 0
    xs = constrain(xs.reshape(B, S, H, P), "batch", "seq", "ssm_inner", None)

    # chunked SSD
    xs_c = xs.reshape(B, nc, Lc, H, P).astype(F32)
    B_c = Bm.reshape(B, nc, Lc, N).astype(F32)
    C_c = Cm.reshape(B, nc, Lc, N).astype(F32)
    dt_c = dt.reshape(B, nc, Lc, H)
    dA = dt_c * A[None, None, None, :]                          # [B,nc,Lc,H]
    cum = jnp.cumsum(dA, axis=2)

    # intra-chunk: Y[t] += sum_{s<=t} (C_t.B_s) exp(cum_t-cum_s) dt_s x_s
    cb = jnp.einsum("bcln,bcsn->bcls", C_c, B_c)                # [B,nc,Lc,Lc]
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)  # [B,nc,L,L,H]
    scores = cb[..., None] * decay * dt_c[:, :, None, :, :]
    y = jnp.einsum("bclsh,bcshp->bclhp", scores, xs_c)

    # chunk summary states + inter-chunk scan
    last = cum[:, :, -1:, :]                                    # [B,nc,1,H]
    sdecay = jnp.exp(last - cum) * dt_c                         # [B,nc,Lc,H]
    S_c = jnp.einsum("bcsh,bcsn,bcshp->bchnp", sdecay, B_c, xs_c)
    chunk_decay = jnp.exp(last[:, :, 0, :])                     # [B,nc,H]

    s0 = (state.ssm.astype(F32) if state is not None
          else jnp.zeros((B, H, N, P), F32))

    def chunk_scan(s_prev, inp):
        dec, s_chunk = inp                                      # [B,H], [B,H,N,P]
        s_new = s_prev * dec[:, :, None, None] + s_chunk
        return s_new, s_prev

    s_final, s_prevs = jax.lax.scan(
        chunk_scan, s0,
        (chunk_decay.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                  # [B,nc,H,N,P]

    y_inter = jnp.einsum("bcln,bchnp,bclh->bclhp",
                         C_c, s_prevs, jnp.exp(cum))
    y = y + y_inter + (p["D"][None, None, None, :, None] * xs_c)
    y = y.reshape(B, S, di).astype(x.dtype)

    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    if return_state:
        return out, SSMState(conv=new_conv, ssm=s_final.astype(F32))
    return out


def mamba2_decode(p: dict, cfg, x: jnp.ndarray, state: SSMState):
    """One-token decode.  x: [B, 1, D]; O(1) in context length."""
    B = x.shape[0]
    di, N, H, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    z = jnp.einsum("bsd,de->bse", x, p["wz"])[:, 0]
    xs = jnp.einsum("bsd,de->bse", x, p["wx"])[:, 0]
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])[:, 0]
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])[:, 0]
    dt_r = jnp.einsum("bsd,dh->bsh", x, p["wdt"])[:, 0]

    u_new = jnp.concatenate([xs, Bm, Cm], axis=-1)              # [B, conv_dim]
    win = jnp.concatenate([state.conv, u_new[:, None, :]], axis=1)  # [B,k,C]
    conv = (win * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
    u = jax.nn.silu(conv.astype(F32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(u, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt_r.astype(F32) + p["dt_bias"])       # [B, H]
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)                                       # [B, H]
    xs_h = xs.reshape(B, H, P).astype(F32)
    dBx = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm.astype(F32), xs_h)
    s_new = state.ssm * dec[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(F32), s_new)
    y = y + p["D"][None, :, None] * xs_h
    y = y.reshape(B, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["wo"])[:, None, :]
    return out, SSMState(conv=win[:, 1:], ssm=s_new)


def ssm_state_pspecs():
    return SSMState(conv=LP("batch", None, "ssm_inner"),
                    ssm=LP("batch", "ssm_inner", None, None))


def init_ssm_state(cfg, B: int, dtype) -> SSMState:
    di, N = cfg.ssm_inner, cfg.ssm_state
    return SSMState(
        conv=jnp.zeros((B, cfg.ssm_conv - 1, di + 2 * N), dtype),
        ssm=jnp.zeros((B, cfg.ssm_heads, N, cfg.ssm_head_dim), F32))
