"""Static analysis over post-SPMD compiled HLO text.

XLA's ``cost_analysis`` (and a naive text grep) counts a while-loop body
ONCE, but scan-over-layers puts almost all compute and every TP/EP
collective inside while bodies — so flat numbers undercount by the trip
count (we measured 100x on a 64-layer model).  This module parses the HLO
into computations, walks the while-loop call graph from ENTRY, extracts
each loop's trip count from its condition's comparison constant, and
accumulates collective traffic weighted by the product of enclosing trip
counts.

Trip-count extraction: a lowered ``lax.scan``'s condition is
``compare(get-tuple-element(iter), constant(N)), direction=LT`` — we take
the max integer constant in the condition computation (and record a
``trip_confidence`` flag when a condition has no constant, defaulting to 1).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_TY_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\),?.*?condition=%?([\w\.\-]+),.*?body=%?([\w\.\-]+)")
_WHILE_RE2 = re.compile(
    r"\bwhile\(.*?\),?.*?body=%?([\w\.\-]+),.*?condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OP_RE = re.compile(r"=\s+(\(?[^()]*(?:\([^)]*\))?[^()=]*?)\s+([a-z\-]+)\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _type_bytes(m) -> int:
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool = False
    collectives: list = dataclasses.field(default_factory=list)  # (op,R,g)
    whiles: list = dataclasses.field(default_factory=list)       # (cond,body)
    max_const: int = 0


def parse_computations(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = Computation(name=hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        ls = line.strip()
        if ls == "}":
            continue
        m = _WHILE_RE.search(ls) or None
        if m:
            cur.whiles.append((m.group(1), m.group(2)))
        else:
            m2 = _WHILE_RE2.search(ls)
            if m2:
                cur.whiles.append((m2.group(2), m2.group(1)))
        for c in _CONST_RE.finditer(ls):
            cur.max_const = max(cur.max_const, int(c.group(1)))
        om = _OP_RE.search(ls)
        if om:
            op = om.group(2)
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES and not op.endswith("-done"):
                restypes = om.group(1)
                R = sum(_type_bytes(t) for t in _TY_RE.finditer(restypes))
                if op.endswith("-start") and restypes.startswith("("):
                    R //= 2   # (operand, result) alias tuple
                g = _group_size(ls)
                cur.collectives.append((base, R, g))
    return comps


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 0


def _wire_bytes(base: str, R: float, g: int) -> float:
    """Per-chip ring traffic for one collective with result bytes R."""
    g = max(g, 1)
    if base == "all-reduce":
        return 2.0 * R * (g - 1) / g
    if base in ("all-gather", "all-to-all"):
        return R * (g - 1) / g
    if base == "reduce-scatter":
        return R * (g - 1)
    return float(R)  # collective-permute


def collective_summary(text: str, default_group: int) -> dict:
    """Trip-count-weighted per-device collective traffic."""
    comps = parse_computations(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"total_wire_bytes": 0.0, "error": "no ENTRY computation"}

    wire = {k: 0.0 for k in COLLECTIVES}
    result = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0.0 for k in COLLECTIVES}
    unknown_trip = [0]

    seen_stack = set()

    def visit(comp: Computation, mult: float):
        if comp.name in seen_stack:       # defensive: no recursion in HLO
            return
        seen_stack.add(comp.name)
        for base, R, g in comp.collectives:
            g = g or default_group
            wire[base] += mult * _wire_bytes(base, R, g)
            result[base] += mult * R
            counts[base] += mult
        for cond_name, body_name in comp.whiles:
            cond = comps.get(cond_name)
            trip = cond.max_const if (cond and cond.max_const > 0) else 1
            if cond is None or cond.max_const == 0:
                unknown_trip[0] += 1
            body = comps.get(body_name)
            if body is not None:
                visit(body, mult * trip)
        seen_stack.discard(comp.name)

    visit(entry, 1.0)
    return {
        "wire_bytes": wire,
        "result_bytes": result,
        "counts": counts,
        "total_wire_bytes": sum(wire.values()),
        "unknown_trip_conditions": unknown_trip[0],
    }


def while_trip_counts(text: str) -> list:
    """Debug helper: [(body_name, trip)] for every while in the module."""
    comps = parse_computations(text)
    out = []
    for c in comps.values():
        for cond_name, body_name in c.whiles:
            cond = comps.get(cond_name)
            out.append((body_name, cond.max_const if cond else -1))
    return out
