"""Analytic FLOPs / HBM-traffic model per (arch x shape) cell.

Why analytic: XLA's cost_analysis counts while-loop bodies once, and all
per-layer compute lives inside the layer scan (hlo.py measures the
undercount at ~trip-count x).  Matmul terms below are exact (they are the
model definition); attention/SSD/WKV terms count the blocks the
implementation actually computes (e.g. the causal flash path computes
masked blocks — that waste is *supposed* to show up in the roofline, and
§Perf iterates on it).  HBM traffic uses a stated coarse model (constants
documented inline); the collective term comes from the HLO walk (hlo.py),
not from here.

All numbers are GLOBAL (whole cluster); divide by mesh size for per-chip.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig, ShapeSpec

BF16 = 2


@dataclasses.dataclass
class CellCost:
    flops_computed: float        # what the implementation executes
    flops_useful: float          # mask-aware / drop-aware useful work
    hbm_bytes: float             # coarse per-step traffic model
    params_bytes: float
    notes: dict


def _attn_proj_flops(cfg: ArchConfig, tokens: float) -> tuple[float, float]:
    """(computed, useful): computed includes zero-masked head padding."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qo_c = 2 * d * cfg.padded_heads * hd * 2       # wq + wo (padded layout)
    kv_c = 2 * d * cfg.padded_kv_heads * hd * 2    # wk + wv
    qo_u = 2 * d * cfg.n_heads * hd * 2
    kv_u = 2 * d * cfg.n_kv_heads * hd * 2
    return tokens * (qo_c + kv_c), tokens * (qo_u + kv_u)


def _attn_score_flops(cfg: ArchConfig, B: float, S: float, causal: bool
                      ) -> tuple[float, float]:
    """(computed, useful) score+PV flops.  The flash path computes every
    block (causal usefulness (S+1)/2S), and computes padded heads."""
    hd = cfg.resolved_head_dim
    full = 4.0 * B * cfg.padded_heads * S * S * hd
    useful = 4.0 * B * cfg.n_heads * S * S * hd \
        * ((S + 1) / (2 * S) if causal else 1.0)
    return full, useful


def _mlp_flops(cfg: ArchConfig, tokens: float) -> float:
    return tokens * 6 * cfg.d_model * cfg.d_ff


def _moe_flops(cfg: ArchConfig, tokens: float) -> tuple[float, float]:
    """(computed incl. capacity padding, useful top-k)."""
    useful = tokens * cfg.experts_per_token * 6 * cfg.d_model * cfg.moe_d_ff
    computed = useful * cfg.capacity_factor
    if cfg.n_shared_experts:
        sh = tokens * 6 * cfg.d_model * cfg.n_shared_experts * cfg.moe_d_ff
        useful += sh
        computed += sh
    # router
    computed += tokens * 2 * cfg.d_model * cfg.n_experts
    useful += tokens * 2 * cfg.d_model * cfg.n_experts
    return computed, useful


def _mamba_flops(cfg: ArchConfig, tokens: float, chunk: int = 128) -> float:
    d, di, N = cfg.d_model, cfg.ssm_inner, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    proj = tokens * 2 * d * (2 * di + 2 * N + H) + tokens * 2 * di * d
    conv = tokens * 2 * cfg.ssm_conv * (di + 2 * N)
    Lc = chunk
    intra = tokens * 2 * Lc * (N + H * P)       # cb + y_intra einsums
    inter = tokens * 4 * H * N * P              # chunk states + y_inter
    return proj + conv + intra + inter


def _rwkv_flops(cfg: ArchConfig, tokens: float, chunk: int = 64) -> float:
    d, dff = cfg.d_model, cfg.d_ff
    hd = cfg.ssm_head_dim
    proj = tokens * 2 * d * d * 6               # r,k,v,g,o + cm_wr
    lora = tokens * 2 * d * 64 * 2
    intra = tokens * 2 * chunk * d * 2          # scores + y einsums
    state = tokens * 4 * d * hd
    cm = tokens * 2 * d * dff * 2
    return proj + lora + intra + state + cm


def _logits_flops(cfg: ArchConfig, tokens: float) -> float:
    return tokens * 2 * cfg.d_model * cfg.padded_vocab


def _per_layer_fwd(cfg: ArchConfig, B: float, S: float):
    """(computed, useful) forward flops for ONE layer of each kind."""
    tokens = B * S
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        pc, pu = _attn_score_flops(cfg, B, S, causal=True)
        prc, pru = _attn_proj_flops(cfg, tokens)
        if fam == "moe":
            fc, fu = _moe_flops(cfg, tokens)
        else:
            fc = fu = _mlp_flops(cfg, tokens)
        return prc + pc + fc, pru + pu + fu
    raise ValueError(fam)


def cell_cost(cfg: ArchConfig, shape: ShapeSpec) -> CellCost:
    B, S = float(shape.global_batch), float(shape.seq_len)
    tokens = B * S
    fam = cfg.family
    notes = {}

    # ---------------- forward flops by family ----------------
    if fam in ("dense", "vlm", "moe"):
        c1, u1 = _per_layer_fwd(cfg, B, S)
        fwd_c, fwd_u = c1 * cfg.n_layers, u1 * cfg.n_layers
    elif fam == "encdec":
        prc, pru = _attn_proj_flops(cfg, tokens)
        pc_e, pu_e = _attn_score_flops(cfg, B, S, causal=False)
        enc = (prc + pc_e + _mlp_flops(cfg, tokens)) * cfg.n_enc_layers
        enc_u = (pru + pu_e + _mlp_flops(cfg, tokens)) * cfg.n_enc_layers
        pc_d, pu_d = _attn_score_flops(cfg, B, S, causal=True)
        pc_x, pu_x = _attn_score_flops(cfg, B, S, causal=False)
        dec_c = (prc * 2 + pc_d + pc_x +
                 _mlp_flops(cfg, tokens)) * cfg.n_layers
        dec_u = (pru * 2 + pu_d + pu_x +
                 _mlp_flops(cfg, tokens)) * cfg.n_layers
        fwd_c, fwd_u = enc + dec_c, enc_u + dec_u
    elif fam == "hybrid":
        m = _mamba_flops(cfg, tokens) * cfg.n_layers
        n_sh = cfg.n_layers // cfg.attn_every
        pc, pu = _attn_score_flops(cfg, B, S, causal=True)
        prc, pru = _attn_proj_flops(cfg, tokens)
        sh_c = (prc + pc + _mlp_flops(cfg, tokens)) * n_sh
        sh_u = (pru + pu + _mlp_flops(cfg, tokens)) * n_sh
        fwd_c, fwd_u = m + sh_c, m + sh_u
    elif fam == "ssm":
        fwd_c = fwd_u = _rwkv_flops(cfg, tokens) * cfg.n_layers
    else:
        raise ValueError(fam)

    fwd_c += _logits_flops(cfg, tokens if shape.kind == "train" else B)
    fwd_u += _logits_flops(cfg, tokens if shape.kind == "train" else B)

    # ---------------- shape kind ----------------
    params_bytes = _params_bytes(cfg)
    if shape.kind == "train":
        mult = 3.0 + (1.0 if cfg.remat else 0.0)   # fwd + bwd(2x) + remat
        flops_c, flops_u = fwd_c * mult, fwd_u * 3.0
        act_traffic = tokens * cfg.d_model * _n_blocks(cfg) * 24 * BF16
        hbm = (params_bytes * (3 + cfg.train_microbatches)
               + 2.5 * _opt_bytes(cfg) + act_traffic)
        notes["remat_extra_fwd"] = cfg.remat
    elif shape.kind == "prefill":
        flops_c, flops_u = fwd_c, fwd_u
        act_traffic = tokens * cfg.d_model * _n_blocks(cfg) * 8 * BF16
        hbm = params_bytes + act_traffic + _cache_bytes(cfg, B, S)
    else:  # decode: one token per sequence against an S-long cache
        dec_c = _decode_flops(cfg, B, S)
        flops_c = flops_u = dec_c
        hbm = _decode_params_touched(cfg, B) + _cache_bytes(cfg, B, S) + \
            B * cfg.d_model * _n_blocks(cfg) * 8 * BF16
        notes["cache_bytes"] = _cache_bytes(cfg, B, S)

    return CellCost(flops_computed=flops_c, flops_useful=flops_u,
                    hbm_bytes=hbm, params_bytes=params_bytes, notes=notes)


def _n_blocks(cfg: ArchConfig) -> int:
    n = cfg.n_layers + (cfg.n_enc_layers or 0)
    if cfg.family == "hybrid":
        n += cfg.n_layers // cfg.attn_every
    return n


def _params_bytes(cfg: ArchConfig) -> float:
    return float(_param_count(cfg)) * BF16


def _param_count(cfg: ArchConfig) -> int:
    import functools
    import jax
    from ..models import init_params
    sds = jax.eval_shape(functools.partial(init_params, cfg),
                         jax.random.PRNGKey(0))
    import math
    return sum(math.prod(x.shape) for x in jax.tree.leaves(sds))


def _opt_bytes(cfg: ArchConfig) -> float:
    per_param = 2.0 if cfg.fsdp else 8.0     # int8 m+v vs f32 m+v
    return _param_count(cfg) * per_param


def _cache_bytes(cfg: ArchConfig, B: float, S: float) -> float:
    hd, kv = cfg.resolved_head_dim, cfg.padded_kv_heads
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return cfg.n_layers * B * S * kv * hd * 2 * BF16
    if fam == "encdec":
        return cfg.n_layers * B * S * kv * hd * 4 * BF16   # self + cross
    if fam == "hybrid":
        n_sh = cfg.n_layers // cfg.attn_every
        attn = n_sh * B * S * kv * hd * 2 * BF16
        ssm = cfg.n_layers * B * cfg.ssm_heads * cfg.ssm_state * \
            cfg.ssm_head_dim * 4
        return attn + ssm
    if fam == "ssm":
        hd6 = cfg.ssm_head_dim
        return cfg.n_layers * B * (cfg.d_model // hd6) * hd6 * hd6 * 4
    raise ValueError(fam)


def _decode_params_touched(cfg: ArchConfig, B: float) -> float:
    """Weight bytes actually read for one decode step: dense weights fully;
    routed experts only those hit by B*k assignments."""
    total = _params_bytes(cfg)
    if not cfg.n_experts:
        return total
    routed = 3 * cfg.n_experts * cfg.d_model * cfg.moe_d_ff * \
        cfg.n_layers * BF16
    frac = min(1.0, B * cfg.experts_per_token / cfg.n_experts)
    return total - routed + routed * frac


def _decode_flops(cfg: ArchConfig, B: float, S: float) -> float:
    """One-token decode: 2*active-params matmuls + cache-read attention."""
    dense = 2.0 * _active_params(cfg) * B
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        attn = 4.0 * B * cfg.n_heads * S * cfg.resolved_head_dim * cfg.n_layers
    elif fam == "encdec":
        attn = 8.0 * B * cfg.n_heads * S * cfg.resolved_head_dim * cfg.n_layers
    elif fam == "hybrid":
        n_sh = cfg.n_layers // cfg.attn_every
        attn = 4.0 * B * cfg.n_heads * S * cfg.resolved_head_dim * n_sh
        attn += 4.0 * B * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim \
            * cfg.n_layers
    else:  # ssm: O(1) state update
        attn = 4.0 * B * cfg.d_model * cfg.ssm_head_dim * cfg.n_layers
    return dense + attn


def _active_params(cfg: ArchConfig) -> float:
    total = _param_count(cfg)
    if not cfg.n_experts:
        return float(total)
    routed = 3 * cfg.n_experts * cfg.d_model * cfg.moe_d_ff * cfg.n_layers
    return float(total - routed
                 + routed * cfg.experts_per_token / cfg.n_experts)
