from . import analytic, hlo
