"""Fault-tolerant checkpointing: msgpack+zstd/zlib leaves, atomic manifest,
content hashes, elastic restore onto a different mesh, async save.

Layout of one checkpoint:
    <dir>/step_000123/
        data.msgpack.zst      leaf payloads (host-gathered numpy; .zlib when
                              zstandard is unavailable — codec is recorded in
                              the manifest and restore dispatches on it)
        MANIFEST.json         step, codec, tree structure, shapes/dtypes, sha256s

Guarantees:
  - Atomicity: everything is written into step_xxx.tmp.<pid> and renamed
    into place only after fsync; a crash mid-save never corrupts the latest
    valid checkpoint (restore scans for the newest dir WITH a manifest).
  - Integrity: per-leaf sha256 recorded and verified on restore.
  - Elasticity: leaves are stored as full (host-replicated) arrays; restore
    takes target shardings and device_puts each leaf, so a checkpoint
    written on one mesh restores onto any other mesh/topology (tested with
    save@1x4 -> restore@2x2 in tests/test_checkpoint.py).
  - Async: save() can run in a background thread (fault-tolerant trainers
    should not stall the step loop); join_pending() fences.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: ~3x faster + denser than zlib, but not in every image
    import zstandard as zstd
except ImportError:
    zstd = None

DEFAULT_CODEC = "zstd" if zstd is not None else "zlib"
_CODEC_EXT = {"zstd": "zst", "zlib": "zlib"}


def _check_codec(codec: str) -> None:
    if codec not in _CODEC_EXT:
        raise ValueError(f"unknown checkpoint codec {codec!r}")
    if codec == "zstd" and zstd is None:
        raise RuntimeError("zstandard not installed; use codec='zlib'")


def compress(blob: bytes, codec: str = DEFAULT_CODEC) -> bytes:
    _check_codec(codec)
    if codec == "zstd":
        return zstd.ZstdCompressor(level=3).compress(blob)
    return zlib.compress(blob, level=6)


def decompress(blob: bytes, codec: str) -> bytes:
    if codec == "zstd" and zstd is None:
        raise RuntimeError(
            "checkpoint was written with zstd but zstandard is not "
            "installed; `pip install zstandard` to restore it")
    _check_codec(codec)
    if codec == "zstd":
        return zstd.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def data_filename(codec: str) -> str:
    return f"data.msgpack.{_CODEC_EXT[codec]}"


_PENDING: list[threading.Thread] = []


def _tree_flatten_with_paths(tree):
    # jax.tree.flatten_with_path only landed in jax 0.4.x-late; the
    # tree_util spelling works across every version this repo supports.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree: Any, extra: Optional[dict] = None,
         async_: bool = False, keep: int = 3,
         codec: str = DEFAULT_CODEC) -> str:
    """Write checkpoint; returns the final path."""
    _check_codec(codec)   # fail in the caller, not the async writer thread
    paths, leaves, _ = _tree_flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

    final = os.path.join(directory, f"step_{step:08d}")

    def _write():
        tmp = final + f".tmp.{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        payload = {}
        manifest_leaves = {}
        for p, arr in zip(paths, host_leaves):
            raw = arr.tobytes()
            payload[p] = raw
            manifest_leaves[p] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(raw).hexdigest(),
            }
        blob = msgpack.packb(payload, use_bin_type=True)
        comp = compress(blob, codec)
        with open(os.path.join(tmp, data_filename(codec)), "wb") as f:
            f.write(comp)
            f.flush()
            os.fsync(f.fileno())
        manifest = {"step": step, "codec": codec, "leaves": manifest_leaves,
                    "extra": extra or {}}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        _write()
    return final


def join_pending() -> None:
    while _PENDING:
        _PENDING.pop().join()


def _gc(directory: str, keep: int) -> None:
    steps = sorted(find_all(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def find_all(directory: str) -> list[int]:
    """All steps with a complete (manifest-bearing) checkpoint."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.count(".tmp"):
            if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def find_latest(directory: str) -> Optional[int]:
    steps = find_all(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, target: Any,
            shardings: Optional[Any] = None, verify: bool = True) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching pytree of jax.sharding
    objects for elastic placement (None -> default device placement)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    codec = manifest.get("codec", "zstd")   # pre-codec manifests were zstd
    with open(os.path.join(path, data_filename(codec)), "rb") as f:
        blob = decompress(f.read(), codec)
    payload = msgpack.unpackb(blob, raw=False)

    paths, leaves, treedef = _tree_flatten_with_paths(target)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for p, like, shd in zip(paths, leaves, shard_leaves):
        meta = manifest["leaves"][p]
        raw = payload[p]
        if verify and hashlib.sha256(raw).hexdigest() != meta["sha256"]:
            raise IOError(f"checkpoint leaf {p} failed integrity check")
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        want_dtype = like.dtype if hasattr(like, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jnp.asarray(arr))
    return treedef.unflatten(out), manifest


def restore_latest(directory: str, target: Any, shardings=None):
    step = find_latest(directory)
    if step is None:
        return None
    tree, manifest = restore(directory, step, target, shardings)
    return step, tree, manifest
