from . import checkpoint
