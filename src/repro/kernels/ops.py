"""Jit'd public wrappers for the scheduler kernels.

``interpret`` auto-selects: the Pallas interpreter executes the kernel
body on CPU for correctness off-TPU; on a real TPU backend the same calls
compile to Mosaic.  Pass ``interpret=True/False`` explicitly to override.
The wrappers here are what the production router (repro.sched.router)
calls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .pod_route import pod_route as _pod_route
from .queue_update import queue_update as _queue_update
from .route_commit import route_commit as _route_commit
from .weighted_argmin import weighted_argmin as _weighted_argmin


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def weighted_argmin(W, cls, inv_rates, **kw):
    """Balanced-Pandas O(M) batched routing (see kernels/weighted_argmin.py)."""
    kw.setdefault("interpret", _interpret_default())
    return _weighted_argmin(W, cls, inv_rates, **kw)


def pod_route(W, cand_idx, cand_cls, valid, inv_rates, **kw):
    """Balanced-Pandas-Pod O(d) batched routing (see kernels/pod_route.py)."""
    kw.setdefault("interpret", _interpret_default())
    return _pod_route(W, cand_idx, cand_cls, valid, inv_rates, **kw)


def queue_update(Q, sel, sel_cls, valid, inv_rates, **kw):
    """Fused routing-batch scatter + workload recompute (see
    kernels/queue_update.py)."""
    kw.setdefault("interpret", _interpret_default())
    return _queue_update(Q, sel, sel_cls, valid, inv_rates, **kw)


def route_commit(Q, valid, inv_rates, **kw):
    """Fused score -> route -> queue-commit of one arrival batch with
    in-kernel sequential conflict resolution (see kernels/route_commit.py).
    Full variant via ``cls=[B, M]``; pod variant via
    ``cand_idx/cand_cls/cand_valid=[B, C]``."""
    kw.setdefault("interpret", _interpret_default())
    return _route_commit(Q, valid, inv_rates, **kw)
