"""Jit'd public wrappers for the scheduler kernels.

``interpret`` defaults to True off-TPU (the Pallas interpreter executes the
kernel body on CPU for correctness); on a real TPU backend the same calls
compile to Mosaic.  The wrappers here are what the production router
(repro.sched.router) calls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .pod_route import pod_route as _pod_route
from .queue_update import queue_update as _queue_update
from .weighted_argmin import weighted_argmin as _weighted_argmin


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def weighted_argmin(W, cls, inv_rates, **kw):
    """Balanced-Pandas O(M) batched routing (see kernels/weighted_argmin.py)."""
    kw.setdefault("interpret", _interpret_default())
    return _weighted_argmin(W, cls, inv_rates, **kw)


def pod_route(W, cand_idx, cand_cls, valid, inv_rates, **kw):
    """Balanced-Pandas-Pod O(d) batched routing (see kernels/pod_route.py)."""
    kw.setdefault("interpret", _interpret_default())
    return _pod_route(W, cand_idx, cand_cls, valid, inv_rates, **kw)


def queue_update(Q, sel, sel_cls, valid, inv_rates, **kw):
    """Fused routing-batch scatter + workload recompute (see
    kernels/queue_update.py)."""
    kw.setdefault("interpret", _interpret_default())
    return _queue_update(Q, sel, sel_cls, valid, inv_rates, **kw)
