"""Shared inverse-rate operand encoding for the scheduler kernels.

All three kernels (weighted_argmin, pod_route, queue_update) take the same
logical operand: per-(server, class) reciprocal service rates.  Callers may
pass either the homogeneous ``[3]`` vector (every server identical — the
paper's symmetric model) or a per-server ``[M, 3]`` matrix (heterogeneous
fleets — GB-PANDAS's motivating asymmetry).  A zero-rate entry (drained /
failed server) has reciprocal rate ``+inf``.

``inf`` cannot ride through the kernels directly: pod_route gathers the
matrix with a one-hot matmul (``0 * inf = NaN`` on every non-selected row)
and a zero workload on a dead server would score ``0 * inf = NaN`` instead
of ``+inf``.  So the host-side encoding splits the operand into lanes the
kernels can consume safely:

  cols 0..2   finite reciprocal rates  (non-finite entries -> 0.0)
  col  3      zero padding
  cols 4..6   dead flags (1.0 where the reciprocal rate was non-finite)
  col  7      zero padding

The kernels multiply workloads by cols 0..2 (never NaN) and mask any
(server, class) whose dead flag is set to ``+inf`` *after* the multiply —
the same guard already applied to pad lanes.  queue_update consumes only
cols 0..2: dead entries contribute 0 to the workload metric, which is safe
because routing masks dead servers by their flag, never by their W.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

CLASSES = 3
WIDTH = 8          # padded lane width: [rates 0..2 | 0 | flags 4..6 | 0]
FLAG_BASE = 4


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Shared ``interpret`` auto-default for every kernel in this package:
    None -> Pallas interpreter everywhere except a real TPU backend (where
    the same call compiles to Mosaic).  Explicit True/False pass through."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def as_matrix(inv_rates: jnp.ndarray, M: int) -> jnp.ndarray:
    """Broadcast a ``[3]`` homogeneous vector to ``[M, 3]``; pass ``[M, 3]``
    through.  Always float32."""
    inv = jnp.asarray(inv_rates, jnp.float32)
    if inv.ndim == 1:
        inv = jnp.broadcast_to(inv[None, :], (M, CLASSES))
    return inv


def encode(inv_rates: jnp.ndarray, M: int, flags: bool = True) -> jnp.ndarray:
    """Finite [M, 8] encoding of a [3] or [M, 3] inverse-rate operand.

    flags=False leaves cols 4..6 zero (queue_update, which only needs the
    finite rates and treats dead entries as contributing no workload).
    """
    inv = as_matrix(inv_rates, M)
    finite = jnp.isfinite(inv)
    enc = jnp.zeros((M, WIDTH), jnp.float32)
    enc = enc.at[:, :CLASSES].set(jnp.where(finite, inv, 0.0))
    if flags:
        enc = enc.at[:, FLAG_BASE:FLAG_BASE + CLASSES].set(
            (~finite).astype(jnp.float32))
    return enc
