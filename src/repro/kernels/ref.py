"""Pure-jnp oracles for the scheduler kernels.

These define the exact semantics the Pallas kernels must reproduce
(tests/test_kernels.py sweeps shapes & dtypes and asserts allclose / exact
index equality).  Tie-breaking contract everywhere: lowest index wins.
"""
from __future__ import annotations

import jax.numpy as jnp


def weighted_argmin_ref(W: jnp.ndarray, cls: jnp.ndarray,
                        inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced-Pandas O(M) routing: full argmin of weighted workload.

    W: [M] workloads; cls: [B, M] int32 locality classes (0/1/2);
    inv_rates: [3] = 1/(alpha,beta,gamma).
    Returns (sel [B] int32, val [B] float32): argmin_m W[m]*inv_rates[cls[b,m]]
    (first index on ties) and the winning score.
    """
    scores = W[None, :].astype(jnp.float32) * inv_rates.astype(jnp.float32)[cls]
    sel = jnp.argmin(scores, axis=1).astype(jnp.int32)
    val = jnp.min(scores, axis=1)
    return sel, val


def pod_route_ref(W: jnp.ndarray, cand_idx: jnp.ndarray, cand_cls: jnp.ndarray,
                  valid: jnp.ndarray, inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced-Pandas-Pod O(d) routing: argmin over an explicit candidate list.

    W: [M]; cand_idx/cand_cls: [B, C] int32; valid: [B, C] bool;
    inv_rates: [3].  Returns (sel [B] int32 server index, val [B] score).
    Invalid candidate slots never win (score +inf); ties -> lowest slot c,
    and the returned server is cand_idx[b, c*].
    """
    w = W.astype(jnp.float32)[cand_idx]                      # [B, C]
    scores = w * inv_rates.astype(jnp.float32)[cand_cls]
    scores = jnp.where(valid, scores, jnp.inf)
    c = jnp.argmin(scores, axis=1)
    sel = jnp.take_along_axis(cand_idx, c[:, None], axis=1)[:, 0].astype(jnp.int32)
    val = jnp.min(scores, axis=1)
    return sel, val


def queue_update_ref(Q: jnp.ndarray, sel: jnp.ndarray, sel_cls: jnp.ndarray,
                     valid: jnp.ndarray, inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused post-routing queue scatter + workload recompute.

    Q: [M, 3] int32 sub-queue lengths; sel/sel_cls: [B] int32; valid: [B] bool.
    Returns (Q_new [M,3] int32, W [M] float32) where
    Q_new = Q + scatter_add(one_hot(sel) x one_hot(sel_cls) * valid) and
    W = Q_new @ inv_rates (paper's W_m = Q^l/a + Q^k/b + Q^r/g).
    """
    upd = jnp.zeros_like(Q).at[sel, sel_cls].add(valid.astype(Q.dtype))
    Q_new = Q + upd
    W = (Q_new.astype(jnp.float32) * inv_rates.astype(jnp.float32)[None, :]).sum(-1)
    return Q_new, W
