"""Pure-jnp oracles for the scheduler kernels.

These define the exact semantics the Pallas kernels must reproduce
(tests/test_kernels.py sweeps shapes & dtypes and asserts allclose / exact
index equality).  Tie-breaking contract everywhere: lowest index wins.

Inverse-rate operand (all three oracles): either the homogeneous ``[3]``
vector (every server identical) or a per-server ``[M, 3]`` matrix
(heterogeneous fleets).  A zero-rate (drained / failed) server has
reciprocal rate ``+inf``; the routing oracles mask such entries to a
``+inf`` score AFTER the multiply — so a zero workload on a dead server
scores ``+inf``, never ``0 * inf = NaN`` — and queue_update counts them as
contributing 0 workload (routing never consults a dead server's W).
"""
from __future__ import annotations

import jax.numpy as jnp


def _rate_factor(inv_rates: jnp.ndarray, idx: jnp.ndarray,
                 cls: jnp.ndarray) -> jnp.ndarray:
    """inv_rates[idx, cls] for the [M, 3] form, inv_rates[cls] for [3].
    idx/cls broadcast together."""
    inv = jnp.asarray(inv_rates, jnp.float32)
    if inv.ndim == 1:
        return inv[cls]
    return inv[idx, cls]


def _guarded_scores(w: jnp.ndarray, factor: jnp.ndarray,
                    valid: jnp.ndarray) -> jnp.ndarray:
    """w * factor with invalid slots and non-finite factors -> +inf (the
    dead-server mask lands after the multiply: no 0 * inf NaNs)."""
    return jnp.where(valid & jnp.isfinite(factor),
                     w.astype(jnp.float32) * factor, jnp.inf)


def weighted_argmin_ref(W: jnp.ndarray, cls: jnp.ndarray,
                        inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced-Pandas O(M) routing: full argmin of weighted workload.

    W: [M] workloads; cls: [B, M] int32 locality classes (0/1/2);
    inv_rates: [3] = 1/(alpha,beta,gamma), or [M, 3] per-server.
    Returns (sel [B] int32, val [B] float32): argmin_m W[m]*inv_rates[m,cls]
    (first index on ties; zero-rate entries score +inf) and the winning
    score.
    """
    m = jnp.arange(cls.shape[-1], dtype=jnp.int32)[None, :]
    factor = _rate_factor(inv_rates, m, cls)                 # [B, M]
    scores = _guarded_scores(W[None, :], factor, jnp.ones(cls.shape, bool))
    sel = jnp.argmin(scores, axis=1).astype(jnp.int32)
    val = jnp.min(scores, axis=1)
    return sel, val


def pod_route_ref(W: jnp.ndarray, cand_idx: jnp.ndarray, cand_cls: jnp.ndarray,
                  valid: jnp.ndarray, inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced-Pandas-Pod O(d) routing: argmin over an explicit candidate list.

    W: [M]; cand_idx/cand_cls: [B, C] int32; valid: [B, C] bool;
    inv_rates: [3] or [M, 3].  Returns (sel [B] int32 server index,
    val [B] score).  Invalid candidate slots and zero-rate (non-finite
    inverse-rate) candidates never win (score +inf); ties -> lowest slot c,
    and the returned server is cand_idx[b, c*].
    """
    w = W.astype(jnp.float32)[cand_idx]                      # [B, C]
    factor = _rate_factor(inv_rates, cand_idx, cand_cls)
    scores = _guarded_scores(w, factor, valid)
    c = jnp.argmin(scores, axis=1)
    sel = jnp.take_along_axis(cand_idx, c[:, None], axis=1)[:, 0].astype(jnp.int32)
    val = jnp.min(scores, axis=1)
    return sel, val


def queue_update_ref(Q: jnp.ndarray, sel: jnp.ndarray, sel_cls: jnp.ndarray,
                     valid: jnp.ndarray, inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused post-routing queue scatter + workload recompute.

    Q: [M, 3] int32 sub-queue lengths; sel/sel_cls: [B] int32; valid: [B] bool.
    inv_rates: [3] or [M, 3].  Returns (Q_new [M,3] int32, W [M] float32)
    where Q_new = Q + scatter_add(one_hot(sel) x one_hot(sel_cls) * valid)
    and W = (Q_new * inv_rates).sum(-1) (paper's W_m = Q^l/a + Q^k/b + Q^r/g,
    with each server's own rates in the [M, 3] form; non-finite entries
    contribute 0).
    """
    upd = jnp.zeros_like(Q).at[sel, sel_cls].add(valid.astype(Q.dtype))
    Q_new = Q + upd
    inv = jnp.asarray(inv_rates, jnp.float32)
    if inv.ndim == 1:
        inv = inv[None, :]
    inv = jnp.where(jnp.isfinite(inv), inv, 0.0)
    W = (Q_new.astype(jnp.float32) * inv).sum(-1)
    return Q_new, W
