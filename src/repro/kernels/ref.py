"""Pure-jnp oracles for the scheduler kernels.

These define the exact semantics the Pallas kernels must reproduce
(tests/test_kernels.py sweeps shapes & dtypes and asserts allclose / exact
index equality).  Tie-breaking contract: lowest index wins for the three
legacy snapshot kernels; the fused ``route_commit`` megakernel instead
breaks exact score ties by locality class first (LOCAL < RACK < REMOTE),
then lowest server index / candidate slot — see ``route_commit_ref``.

Inverse-rate operand (all three oracles): either the homogeneous ``[3]``
vector (every server identical) or a per-server ``[M, 3]`` matrix
(heterogeneous fleets).  A zero-rate (drained / failed) server has
reciprocal rate ``+inf``; the routing oracles mask such entries to a
``+inf`` score AFTER the multiply — so a zero workload on a dead server
scores ``+inf``, never ``0 * inf = NaN`` — and queue_update counts them as
contributing 0 workload (routing never consults a dead server's W).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _rate_factor(inv_rates: jnp.ndarray, idx: jnp.ndarray,
                 cls: jnp.ndarray) -> jnp.ndarray:
    """inv_rates[idx, cls] for the [M, 3] form, inv_rates[cls] for [3].
    idx/cls broadcast together."""
    inv = jnp.asarray(inv_rates, jnp.float32)
    if inv.ndim == 1:
        return inv[cls]
    return inv[idx, cls]


def _guarded_scores(w: jnp.ndarray, factor: jnp.ndarray,
                    valid: jnp.ndarray) -> jnp.ndarray:
    """w * factor with invalid slots and non-finite factors -> +inf (the
    dead-server mask lands after the multiply: no 0 * inf NaNs)."""
    return jnp.where(valid & jnp.isfinite(factor),
                     w.astype(jnp.float32) * factor, jnp.inf)


def weighted_argmin_ref(W: jnp.ndarray, cls: jnp.ndarray,
                        inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced-Pandas O(M) routing: full argmin of weighted workload.

    W: [M] workloads; cls: [B, M] int32 locality classes (0/1/2);
    inv_rates: [3] = 1/(alpha,beta,gamma), or [M, 3] per-server.
    Returns (sel [B] int32, val [B] float32): argmin_m W[m]*inv_rates[m,cls]
    (first index on ties; zero-rate entries score +inf) and the winning
    score.
    """
    m = jnp.arange(cls.shape[-1], dtype=jnp.int32)[None, :]
    factor = _rate_factor(inv_rates, m, cls)                 # [B, M]
    scores = _guarded_scores(W[None, :], factor, jnp.ones(cls.shape, bool))
    sel = jnp.argmin(scores, axis=1).astype(jnp.int32)
    val = jnp.min(scores, axis=1)
    return sel, val


def pod_route_ref(W: jnp.ndarray, cand_idx: jnp.ndarray, cand_cls: jnp.ndarray,
                  valid: jnp.ndarray, inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced-Pandas-Pod O(d) routing: argmin over an explicit candidate list.

    W: [M]; cand_idx/cand_cls: [B, C] int32; valid: [B, C] bool;
    inv_rates: [3] or [M, 3].  Returns (sel [B] int32 server index,
    val [B] score).  Invalid candidate slots and zero-rate (non-finite
    inverse-rate) candidates never win (score +inf); ties -> lowest slot c,
    and the returned server is cand_idx[b, c*].
    """
    w = W.astype(jnp.float32)[cand_idx]                      # [B, C]
    factor = _rate_factor(inv_rates, cand_idx, cand_cls)
    scores = _guarded_scores(w, factor, valid)
    c = jnp.argmin(scores, axis=1)
    sel = jnp.take_along_axis(cand_idx, c[:, None], axis=1)[:, 0].astype(jnp.int32)
    val = jnp.min(scores, axis=1)
    return sel, val


def queue_update_ref(Q: jnp.ndarray, sel: jnp.ndarray, sel_cls: jnp.ndarray,
                     valid: jnp.ndarray, inv_rates: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused post-routing queue scatter + workload recompute.

    Q: [M, 3] int32 sub-queue lengths; sel/sel_cls: [B] int32; valid: [B] bool.
    inv_rates: [3] or [M, 3].  Returns (Q_new [M,3] int32, W [M] float32)
    where Q_new = Q + scatter_add(one_hot(sel) x one_hot(sel_cls) * valid)
    and W = (Q_new * inv_rates).sum(-1) (paper's W_m = Q^l/a + Q^k/b + Q^r/g,
    with each server's own rates in the [M, 3] form; non-finite entries
    contribute 0).
    """
    upd = jnp.zeros_like(Q).at[sel, sel_cls].add(valid.astype(Q.dtype))
    Q_new = Q + upd
    inv = jnp.asarray(inv_rates, jnp.float32)
    if inv.ndim == 1:
        inv = inv[None, :]
    inv = jnp.where(jnp.isfinite(inv), inv, 0.0)
    W = (Q_new.astype(jnp.float32) * inv).sum(-1)
    return Q_new, W


def _finite_dead(inv_rates: jnp.ndarray, M: int):
    """(finite reciprocal rates [M, 3], dead mask [M, 3]) — the oracle-side
    mirror of the kernels' invrates encoding."""
    inv = jnp.asarray(inv_rates, jnp.float32)
    if inv.ndim == 1:
        inv = jnp.broadcast_to(inv[None, :], (M, 3))
    finite = jnp.isfinite(inv)
    return jnp.where(finite, inv, 0.0), ~finite


_RANK_BIG = jnp.int32(2**30)


def route_commit_ref(Q: jnp.ndarray, valid: jnp.ndarray,
                     inv_rates: jnp.ndarray, *,
                     cls: Optional[jnp.ndarray] = None,
                     prio: Optional[jnp.ndarray] = None,
                     cand_idx: Optional[jnp.ndarray] = None,
                     cand_cls: Optional[jnp.ndarray] = None,
                     cand_valid: Optional[jnp.ndarray] = None):
    """Sequential-commit routing oracle for the route_commit megakernel.

    Routes arrivals IN ORDER: arrival b scores against ``W0 + dW`` where
    ``dW`` holds the commits of arrivals ``0..b-1`` (``+inv_rates[sel,
    cls]`` each, 0 for dead servers) — the paper's per-arrival model, not
    a shared snapshot.  Scores are ``(W0 + dW) * inv_rates[m, cls]`` with
    dead / invalid entries masked to ``+inf`` after the multiply.  Exact
    ties break by locality class first, then the optional per-server
    ``prio`` lane (full variant; lower wins — a random permutation gives
    the unbiased ties the sequential path uses), then lowest server index
    (full variant, ``cls [B, M]``) or lowest candidate slot (pod variant,
    ``cand_idx``/``cand_cls``/``cand_valid [B, C]``; invalid slots lose
    every tie and can only win when every slot scores ``+inf``).  Arrivals
    with ``valid[b]`` False still receive a routing decision but commit
    nothing.

    Returns (Q_new [M, 3] int32, W_new [M] f32, sel [B] int32,
    sel_cls [B] int32, val [B] f32).
    """
    M = Q.shape[0]
    finite, dead = _finite_dead(inv_rates, M)
    W0 = (Q.astype(jnp.float32) * finite).sum(-1)

    if cls is not None:
        m = jnp.arange(M, dtype=jnp.int32)
        p = (m if prio is None else prio.astype(jnp.int32))

        def step(dw, xs):
            cls_b, v_b = xs
            factor = finite[m, cls_b]
            ok = (cls_b < 3) & ~dead[m, cls_b]
            scores = jnp.where(ok, (W0 + dw) * factor, jnp.inf)
            best = jnp.min(scores)
            rank = jnp.where(scores == best,
                             (cls_b * M + p) * M + m, _RANK_BIG)
            rb = jnp.min(rank)
            sel = (rb % M).astype(jnp.int32)
            scls = (rb // (M * M)).astype(jnp.int32)
            amt = finite[sel, jnp.minimum(scls, 2)] * (scls < 3)
            dw = dw + jnp.where((m == sel) & v_b, amt, 0.0)
            return dw, (sel, scls, best)

        xs = (cls.astype(jnp.int32), jnp.asarray(valid, bool))
    else:
        assert cand_idx is not None and cand_cls is not None \
            and cand_valid is not None
        C = cand_idx.shape[1]
        slot = jnp.arange(C, dtype=jnp.int32)
        m = jnp.arange(M, dtype=jnp.int32)

        def step(dw, xs):
            ci, cc, cv, v_b = xs
            factor = finite[ci, cc]
            ok = (cv > 0) & (cc < 3) & ~dead[ci, cc]
            scores = jnp.where(ok, (W0 + dw)[ci] * factor, jnp.inf)
            best = jnp.min(scores)
            rank = jnp.where(scores == best,
                             cc * C + slot + (1 - cv) * 4 * C, _RANK_BIG)
            s = (jnp.min(rank) % C).astype(jnp.int32)
            sel = ci[s]
            scls = cc[s]
            dw = dw + jnp.where((m == sel) & v_b, factor[s], 0.0)
            return dw, (sel, scls, best)

        xs = (cand_idx.astype(jnp.int32), cand_cls.astype(jnp.int32),
              jnp.asarray(cand_valid, jnp.int32),
              jnp.asarray(valid, bool))

    dw, (sel, scls, val) = jax.lax.scan(step, jnp.zeros(M, jnp.float32), xs)
    v = jnp.asarray(valid, bool)
    Q_new = Q + jnp.zeros_like(Q).at[sel, jnp.minimum(scls, 2)].add(
        (v & (scls < 3)).astype(Q.dtype))
    return Q_new, W0 + dw, sel, scls, val


def route_commit_wseq(Q: jnp.ndarray, sel: jnp.ndarray, sel_cls: jnp.ndarray,
                      valid: jnp.ndarray, inv_rates: jnp.ndarray) -> jnp.ndarray:
    """Replay the PRE-commit workload each arrival routed against: [B, M].

    Row b is ``W0 + (commits of arrivals 0..b-1)`` — exactly what
    route_commit scored arrival b with.  Used by the telemetry probe hooks
    to rank batched decisions against the evolving O(M) oracle instead of
    a stale slot-start snapshot.
    """
    M = Q.shape[0]
    finite, _ = _finite_dead(inv_rates, M)
    W0 = (Q.astype(jnp.float32) * finite).sum(-1)
    m = jnp.arange(M, dtype=jnp.int32)

    def step(dw, xs):
        s, c, v = xs
        wpre = W0 + dw
        amt = finite[s, jnp.minimum(c, 2)] * (c < 3)
        dw = dw + jnp.where((m == s) & v, amt, 0.0)
        return dw, wpre

    _, wseq = jax.lax.scan(
        step, jnp.zeros(M, jnp.float32),
        (sel.astype(jnp.int32), sel_cls.astype(jnp.int32),
         jnp.asarray(valid, bool)))
    return wseq
