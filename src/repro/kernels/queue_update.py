"""Pallas TPU kernel: fused routing-batch scatter + workload recompute.

After a routing batch is decided (sel[b] = server, sel_cls[b] = locality
class), the scheduler must apply Q[sel, cls] += 1 for every task and refresh
the per-server workloads W_m = Q^l/alpha + Q^k/beta + Q^r/gamma (paper
§IV-A).  A naive scatter serializes on collisions; on TPU we express the
scatter as a matmul — dQ = one_hot(sel)^T @ one_hot(cls), contracting over
the batch — which the MXU executes collision-free, and fuse the workload
recompute into the same VMEM residency (Q is read and written once).

Grid tiles the server axis; the whole routing batch is VMEM-resident per
step (B*m_tile one-hot ~= 1024*512*4 = 2 MiB).

Heterogeneous-rate contract (``inv_rates``: [3] or [M, 3]): the workload
refresh uses each server's own row, W_m = sum_c Q[m, c] * inv_rates[m, c].
The wrapper encodes the operand (invrates.encode, flags=False) as a
per-server [Mp, 8] block whose cols 0..2 are the finite reciprocal rates;
non-finite (zero-rate / drained) entries contribute 0 to W — safe because
the routing kernels mask dead servers by their own dead flags, never by W.
Oracle: ref.queue_update_ref.
"""
from __future__ import annotations

from typing import Optional

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .invrates import WIDTH, encode, resolve_interpret

LANE = 128


def _kernel(q_ref, sel_ref, cls_ref, valid_ref, invr_ref, qout_ref, w_ref,
             *, m_tile: int, b_pad: int):
    j = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)             # [m_tile, 8] (3 used)
    sel = sel_ref[...]                              # [1, B]
    cls = cls_ref[...]
    valid = valid_ref[...]

    base = j * m_tile
    # one_hot over servers in this tile: [B, m_tile]
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (b_pad, m_tile), 1) + base
    oh_sel = ((iota_m == sel.reshape(b_pad, 1)) & (valid.reshape(b_pad, 1) > 0)
              ).astype(jnp.float32)
    # one_hot over the 3 classes (padded to 8 lanes): [B, 8]
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (b_pad, 8), 1)
    oh_cls = (iota_c == cls.reshape(b_pad, 1)).astype(jnp.float32)

    dq = jax.lax.dot_general(oh_sel, oh_cls, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [m_tile, 8]
    q_new = q + dq
    qout_ref[...] = q_new.astype(jnp.int32)

    ir = invr_ref[...]                              # [m_tile, 8] (3 used, rest 0)
    w_ref[...] = jnp.sum(q_new * ir, axis=1, keepdims=True)  # [m_tile, 1]


@functools.partial(jax.jit, static_argnames=("m_tile", "interpret"))
def queue_update(Q: jnp.ndarray, sel: jnp.ndarray, sel_cls: jnp.ndarray,
                 valid: jnp.ndarray, inv_rates: jnp.ndarray, *,
                 m_tile: int = 4 * LANE, interpret: Optional[bool] = None
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """See ref.queue_update_ref.  Q: [M, 3] int32; sel/sel_cls/valid: [B];
    inv_rates: [3] homogeneous or [M, 3] per-server (non-finite entries
    contribute 0 to W)."""
    M, three = Q.shape
    assert three == 3
    (B,) = sel.shape
    Mp = -(-M // m_tile) * m_tile
    Bp = max(8, -(-B // 8) * 8)

    q_p = jnp.pad(Q.astype(jnp.int32), ((0, Mp - M), (0, 5)))      # [Mp, 8]
    pad1 = lambda x, fill: jnp.pad(x.astype(jnp.int32), (0, Bp - B),
                                   constant_values=fill)[None, :]
    sel_p = pad1(sel, M)          # padded tasks point past every tile
    cls_p = pad1(sel_cls, 3)
    valid_p = pad1(valid.astype(jnp.int32), 0)
    invr = jnp.pad(encode(inv_rates, M, flags=False),
                   ((0, Mp - M), (0, 0)))                          # [Mp, 8]

    q_new, W = pl.pallas_call(
        functools.partial(_kernel, m_tile=m_tile, b_pad=Bp),
        grid=(Mp // m_tile,),
        in_specs=[
            pl.BlockSpec((m_tile, 8), lambda j: (j, 0)),
            pl.BlockSpec((1, Bp), lambda j: (0, 0)),
            pl.BlockSpec((1, Bp), lambda j: (0, 0)),
            pl.BlockSpec((1, Bp), lambda j: (0, 0)),
            pl.BlockSpec((m_tile, WIDTH), lambda j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m_tile, 8), lambda j: (j, 0)),
            pl.BlockSpec((m_tile, 1), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, 8), jnp.int32),
            jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q_p, sel_p, cls_p, valid_p, invr)
    return q_new[:M, :3], W[:M, 0]
