"""Pallas TPU kernel: power-of-d-choices routing over explicit candidates.

The paper's contribution made concrete at the kernel level: instead of
streaming all M workloads per task (weighted_argmin.py), each task probes
only C = n_replicas + d candidates (paper §IV-C: C = 11 for d = 8 — 2.2% of
M = 500).  The kernel's memory traffic per task drops from O(M) to O(d), the
same O(M) -> O(1) reduction the paper proves for scheduler messaging.

TPU mapping: the candidate gather W[cand_idx] is expressed as a one-hot
matmul (one_hot(cand_idx) @ W) — the idiomatic TPU formulation of a small
gather, which lands on the MXU instead of requiring scatter/gather support —
and the argmin over the C candidate slots stays on the VPU.  The full W
vector is resident in VMEM (M <= ~64k fits comfortably); the grid tiles the
task batch.

Heterogeneous-rate contract (``inv_rates``: [3] or [M, 3])
----------------------------------------------------------
The inverse-rate operand is either the homogeneous [3] vector or a
per-server [M, 3] matrix.  The per-candidate rate gather
inv_rates[cand_idx[b, c], cand_cls[b, c]] reuses the SAME one-hot matmul
already built for the workload gather: the wrapper encodes the matrix
(invrates.encode) as [Mp, 8] — cols 0..2 finite reciprocal rates, cols 4..6
dead flags for zero-rate (reciprocal ``+inf``) entries — one_hot @ enc
gathers all eight lanes at once, and the class column is selected on the
VPU.  score(b, c) = W[cand] * inv_rates[cand, cls] when that entry is
finite, else ``+inf``; the dead mask lands AFTER the multiply (same guard
as pad/invalid slots) so a zero-workload dead candidate scores ``+inf``
rather than ``0 * inf = NaN``.  Oracle: ref.pod_route_ref.
"""
from __future__ import annotations

from typing import Optional

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .invrates import FLAG_BASE, WIDTH, encode, resolve_interpret

LANE = 128


def _kernel(w_ref, idx_ref, cls_ref, valid_ref, invm_ref, sel_ref, val_ref,
             *, m_pad: int, c_pad: int, b_tile: int):
    w = w_ref[...].astype(jnp.float32)            # [1, Mp]
    cand = idx_ref[...]                            # [b, C]
    cls = cls_ref[...]                             # [b, C]
    valid = valid_ref[...]                         # [b, C] (int32 0/1)
    invm = invm_ref[...]                           # [Mp, 8] (see invrates)

    # gather-as-matmul: one_hot([b*C, Mp]) @ W[Mp] -> scores per candidate,
    # and the same one-hot gathers the candidate's inverse-rate lanes.
    flat = cand.reshape(b_tile * c_pad, 1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (b_tile * c_pad, m_pad), 1)
    onehot = (iota == flat).astype(jnp.float32)
    wc = jax.lax.dot_general(onehot, w[0, :],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    wc = wc.reshape(b_tile, c_pad)
    irc = jax.lax.dot_general(onehot, invm,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [b*C, 8]

    def col(k):
        return irc[:, k].reshape(b_tile, c_pad)

    factor = jnp.where(cls == 0, col(0), jnp.where(cls == 1, col(1), col(2)))
    dead = jnp.where(cls == 0, col(FLAG_BASE),
                     jnp.where(cls == 1, col(FLAG_BASE + 1),
                               col(FLAG_BASE + 2)))
    scores = jnp.where((valid > 0) & (cls < 3) & (dead == 0.0),
                       wc * factor, jnp.inf)       # [b, C]

    c_star = jnp.argmin(scores, axis=1).astype(jnp.int32)  # first-slot ties
    # select cand_idx[b, c*] without a gather: one-hot dot over the C axis.
    pickmask = (jax.lax.broadcasted_iota(jnp.int32, (b_tile, c_pad), 1)
                == c_star[:, None])
    sel_ref[...] = jnp.sum(jnp.where(pickmask, cand, 0), axis=1).astype(jnp.int32)
    val_ref[...] = jnp.min(scores, axis=1)


@functools.partial(jax.jit, static_argnames=("b_tile", "interpret"))
def pod_route(W: jnp.ndarray, cand_idx: jnp.ndarray, cand_cls: jnp.ndarray,
              valid: jnp.ndarray, inv_rates: jnp.ndarray, *,
              b_tile: int = 8,
              interpret: Optional[bool] = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """See ref.pod_route_ref.  W: [M]; cand_idx/cand_cls: [B, C]; valid: [B, C];
    inv_rates: [3] homogeneous or [M, 3] per-server (entries may be +inf for
    zero-rate servers — masked to +inf scores, never NaN).

    Pads C to a multiple of 8 lanes-worth and B to b_tile.  VMEM per step
    ~= b_tile*C*M*4 bytes for the one-hot (b_tile=8, C=16, M=8192 -> 4 MiB).
    """
    B, C = cand_idx.shape
    (M,) = W.shape
    Bp = -(-B // b_tile) * b_tile
    Cp = max(8, -(-C // 8) * 8)
    Mp = -(-M // LANE) * LANE

    W_p = jnp.pad(W.astype(jnp.float32), (0, Mp - M))[None, :]
    pad2 = lambda x, fill: jnp.pad(x.astype(jnp.int32),
                                   ((0, Bp - B), (0, Cp - C)),
                                   constant_values=fill)
    idx_p = pad2(cand_idx, 0)
    cls_p = pad2(cand_cls, 3)
    valid_p = pad2(valid.astype(jnp.int32), 0)
    invm = jnp.pad(encode(inv_rates, M), ((0, Mp - M), (0, 0)))  # [Mp, 8]

    sel, val = pl.pallas_call(
        functools.partial(_kernel, m_pad=Mp, c_pad=Cp, b_tile=b_tile),
        grid=(Bp // b_tile,),
        in_specs=[
            pl.BlockSpec((1, Mp), lambda i: (0, 0)),
            pl.BlockSpec((b_tile, Cp), lambda i: (i, 0)),
            pl.BlockSpec((b_tile, Cp), lambda i: (i, 0)),
            pl.BlockSpec((b_tile, Cp), lambda i: (i, 0)),
            pl.BlockSpec((Mp, WIDTH), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b_tile,), lambda i: (i,)),
            pl.BlockSpec((b_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(W_p, idx_p, cls_p, valid_p, invm)
    return sel[:B], val[:B]
