"""Pallas TPU kernel: power-of-d-choices routing over explicit candidates.

The paper's contribution made concrete at the kernel level: instead of
streaming all M workloads per task (weighted_argmin.py), each task probes
only C = n_replicas + d candidates (paper §IV-C: C = 11 for d = 8 — 2.2% of
M = 500).  The kernel's memory traffic per task drops from O(M) to O(d), the
same O(M) -> O(1) reduction the paper proves for scheduler messaging.

TPU mapping: the candidate gather W[cand_idx] is expressed as a one-hot
matmul (one_hot(cand_idx) @ W) — the idiomatic TPU formulation of a small
gather, which lands on the MXU instead of requiring scatter/gather support —
and the argmin over the C candidate slots stays on the VPU.  The full W
vector is resident in VMEM (M <= ~64k fits comfortably); the grid tiles the
task batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(w_ref, idx_ref, cls_ref, valid_ref, invr_ref, sel_ref, val_ref,
             *, m_pad: int, c_pad: int, b_tile: int):
    w = w_ref[...].astype(jnp.float32)            # [1, Mp]
    cand = idx_ref[...]                            # [b, C]
    cls = cls_ref[...]                             # [b, C]
    valid = valid_ref[...]                         # [b, C] (int32 0/1)

    # gather-as-matmul: one_hot([b*C, Mp]) @ W[Mp] -> scores per candidate.
    flat = cand.reshape(b_tile * c_pad, 1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (b_tile * c_pad, m_pad), 1)
    onehot = (iota == flat).astype(jnp.float32)
    wc = jax.lax.dot_general(onehot, w[0, :],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    wc = wc.reshape(b_tile, c_pad)

    ir0 = invr_ref[0, 0]
    ir1 = invr_ref[0, 1]
    ir2 = invr_ref[0, 2]
    factor = jnp.where(cls == 0, ir0, jnp.where(cls == 1, ir1, ir2))
    scores = jnp.where((valid > 0) & (cls < 3), wc * factor, jnp.inf)  # [b, C]

    c_star = jnp.argmin(scores, axis=1).astype(jnp.int32)  # first-slot ties
    # select cand_idx[b, c*] without a gather: one-hot dot over the C axis.
    pickmask = (jax.lax.broadcasted_iota(jnp.int32, (b_tile, c_pad), 1)
                == c_star[:, None])
    sel_ref[...] = jnp.sum(jnp.where(pickmask, cand, 0), axis=1).astype(jnp.int32)
    val_ref[...] = jnp.min(scores, axis=1)


@functools.partial(jax.jit, static_argnames=("b_tile", "interpret"))
def pod_route(W: jnp.ndarray, cand_idx: jnp.ndarray, cand_cls: jnp.ndarray,
              valid: jnp.ndarray, inv_rates: jnp.ndarray, *,
              b_tile: int = 8, interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """See ref.pod_route_ref.  W: [M]; cand_idx/cand_cls: [B, C]; valid: [B, C].

    Pads C to a multiple of 8 lanes-worth and B to b_tile.  VMEM per step
    ~= b_tile*C*M*4 bytes for the one-hot (b_tile=8, C=16, M=8192 -> 4 MiB).
    """
    B, C = cand_idx.shape
    (M,) = W.shape
    Bp = -(-B // b_tile) * b_tile
    Cp = max(8, -(-C // 8) * 8)
    Mp = -(-M // LANE) * LANE

    W_p = jnp.pad(W.astype(jnp.float32), (0, Mp - M))[None, :]
    pad2 = lambda x, fill: jnp.pad(x.astype(jnp.int32),
                                   ((0, Bp - B), (0, Cp - C)),
                                   constant_values=fill)
    idx_p = pad2(cand_idx, 0)
    cls_p = pad2(cand_cls, 3)
    valid_p = pad2(valid.astype(jnp.int32), 0)
    invr = jnp.pad(inv_rates.astype(jnp.float32), (0, 1))[None, :]

    sel, val = pl.pallas_call(
        functools.partial(_kernel, m_pad=Mp, c_pad=Cp, b_tile=b_tile),
        grid=(Bp // b_tile,),
        in_specs=[
            pl.BlockSpec((1, Mp), lambda i: (0, 0)),
            pl.BlockSpec((b_tile, Cp), lambda i: (i, 0)),
            pl.BlockSpec((b_tile, Cp), lambda i: (i, 0)),
            pl.BlockSpec((b_tile, Cp), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b_tile,), lambda i: (i,)),
            pl.BlockSpec((b_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
        ],
        interpret=interpret,
    )(W_p, idx_p, cls_p, valid_p, invr)
    return sel[:B], val[:B]
