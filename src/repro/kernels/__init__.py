"""Pallas TPU kernels for the scheduler's compute hot spots.

weighted_argmin — O(M) Balanced-Pandas routing scan (the baseline the paper
                  improves on); pod_route — O(d) power-of-d routing;
queue_update    — fused scatter + workload recompute.  ref.py holds the
pure-jnp oracles; ops.py the jit'd wrappers (interpret=True off-TPU).

All three kernels take their inverse-rate operand as either the homogeneous
``[3]`` vector or a per-server ``[M, 3]`` matrix (heterogeneous fleets);
zero-rate servers carry ``+inf`` inverse rates and are masked to ``+inf``
scores after the multiply (invrates.py documents the finite encoding).
"""
from . import ref
from .ops import pod_route, queue_update, weighted_argmin

__all__ = ["ref", "pod_route", "queue_update", "weighted_argmin"]
