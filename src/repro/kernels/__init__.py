"""Pallas TPU kernels for the scheduler's compute hot spots.

route_commit    — THE batched hot path: fused score -> route -> queue-commit
                  of a whole arrival batch per launch, with in-kernel
                  sequential conflict resolution (arrival b+1 sees arrival
                  b's commit via a VMEM W-delta accumulator) and an exact
                  class-priority tie-break lane.  Full-BP and pod variants
                  behind one wrapper.
weighted_argmin — O(M) Balanced-Pandas snapshot routing (the baseline the
                  paper improves on); pod_route — O(d) power-of-d snapshot
                  routing; queue_update — fused scatter + workload
                  recompute.  These three remain the per-arrival
                  (sequential route_mode) building blocks.

ref.py holds the pure-jnp oracles; ops.py the jit'd wrappers.  ``interpret``
auto-selects per backend (interpreter off-TPU, Mosaic on TPU).

All kernels take their inverse-rate operand as either the homogeneous
``[3]`` vector or a per-server ``[M, 3]`` matrix (heterogeneous fleets);
zero-rate servers carry ``+inf`` inverse rates and are masked to ``+inf``
scores after the multiply (invrates.py documents the finite encoding).
"""
from . import ref
from .ops import pod_route, queue_update, route_commit, weighted_argmin

__all__ = ["ref", "pod_route", "queue_update", "route_commit",
           "weighted_argmin"]
