"""Pallas TPU kernel: batched weighted-workload argmin over all M servers.

This is the compute hot spot of the *baseline* Balanced-Pandas central
scheduler: for every task in a routing batch, scan all M servers' workloads
weighted by the task's locality class (W/alpha locals, W/beta rack-locals,
W/gamma remotes) and take the argmin (paper §IV-A).  At data-center scale
this is a [B, M] streaming reduction — the O(M) cost the paper's Pod variant
eliminates — so we tile M through VMEM and keep a running (min, argmin)
accumulator per task, the canonical cross-block reduction pattern.

TPU mapping notes (DESIGN.md §2): scores are formed on the VPU
(8x128 lanes); the M axis is tiled in multiples of 128 lanes; the running
accumulator lives in the output block, which maps to the same block for every
M-step of the grid (sequential TPU grid => safe accumulation).  Tie-break:
lowest server index (block order + first-index argmin within a block).

Heterogeneous-rate contract (``inv_rates``: [3] or [M, 3])
----------------------------------------------------------
The inverse-rate operand is either the homogeneous [3] vector or a
per-server [M, 3] matrix; both ride the same kernel — the wrapper encodes
them (invrates.encode) as a lane-transposed [8, Mp] block whose rows 0..2
hold finite reciprocal rates per server and rows 4..6 hold dead flags for
zero-rate (reciprocal ``+inf``) entries.  score(b, m) =
W[m] * inv_rates[m, cls[b, m]] when that entry is finite, else ``+inf``:
the dead mask is applied AFTER the multiply, exactly like the pad-lane
guard, so a zero-workload dead server scores ``+inf`` rather than
``0 * inf = NaN``.  Oracle: ref.weighted_argmin_ref.
"""
from __future__ import annotations

from typing import Optional

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .invrates import FLAG_BASE, WIDTH, encode, resolve_interpret

LANE = 128
SUB = 8


def _kernel(w_ref, cls_ref, invr_ref, val_ref, idx_ref, *, m_tile: int):
    j = pl.program_id(1)

    w = w_ref[...].astype(jnp.float32)          # [1, m_tile]
    cls = cls_ref[...]                          # [b_tile, m_tile] int32
    ir = invr_ref[...]                          # [8, m_tile] f32 (see invrates)
    # class -> per-server 1/rate via selects (avoids an in-kernel gather;
    # cls in {0,1,2}); rows 0..2 are the finite rates, rows 4..6 the dead
    # flags.  Padded lanes carry cls=3 and dead entries carry flag=1; both
    # are masked to +inf AFTER the multiply so a zero-workload lane cannot
    # produce 0*inf = NaN.
    factor = jnp.where(cls == 0, ir[0:1, :],
                       jnp.where(cls == 1, ir[1:2, :], ir[2:3, :]))
    dead = jnp.where(cls == 0, ir[FLAG_BASE:FLAG_BASE + 1, :],
                     jnp.where(cls == 1, ir[FLAG_BASE + 1:FLAG_BASE + 2, :],
                               ir[FLAG_BASE + 2:FLAG_BASE + 3, :]))
    scores = jnp.where((cls < 3) & (dead == 0.0), w * factor, jnp.inf)

    local_val = jnp.min(scores, axis=1)
    local_arg = jnp.argmin(scores, axis=1).astype(jnp.int32) + j * m_tile

    @pl.when(j == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    better = local_val < val_ref[...]            # strict: earlier block wins ties
    val_ref[...] = jnp.where(better, local_val, val_ref[...])
    idx_ref[...] = jnp.where(better, local_arg, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("b_tile", "m_tile", "interpret"))
def weighted_argmin(W: jnp.ndarray, cls: jnp.ndarray, inv_rates: jnp.ndarray,
                    *, b_tile: int = SUB, m_tile: int = 4 * LANE,
                    interpret: Optional[bool] = None
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """See ref.weighted_argmin_ref.  W: [M]; cls: [B, M] int32;
    inv_rates: [3] homogeneous or [M, 3] per-server (entries may be +inf
    for zero-rate servers — masked to +inf scores, never NaN).

    Pads B up to b_tile and M up to m_tile (padded servers get class 3 =>
    +inf score; padded tasks are sliced off), then launches a
    (B/b_tile, M/m_tile) grid.  VMEM per step ~= b_tile*m_tile*8 bytes.
    """
    B, M = cls.shape
    Bp = -(-B // b_tile) * b_tile
    Mp = -(-M // m_tile) * m_tile
    W_p = jnp.pad(W.astype(jnp.float32), (0, Mp - M))[None, :]     # [1, Mp]
    cls_p = jnp.pad(cls.astype(jnp.int32), ((0, Bp - B), (0, Mp - M)),
                    constant_values=3)
    invr = jnp.pad(encode(inv_rates, M), ((0, Mp - M), (0, 0))).T  # [8, Mp]

    grid = (Bp // b_tile, Mp // m_tile)
    val, idx = pl.pallas_call(
        functools.partial(_kernel, m_tile=m_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m_tile), lambda i, j: (0, j)),
            pl.BlockSpec((b_tile, m_tile), lambda i, j: (i, j)),
            pl.BlockSpec((WIDTH, m_tile), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((b_tile,), lambda i, j: (i,)),
            pl.BlockSpec((b_tile,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(W_p, cls_p, invr)
    return idx[:B], val[:B]
