"""Pallas TPU megakernel: fused score -> route -> queue-commit for a batch.

The batched scheduler hot path used to be three HBM round-trips per slot:
recompute W from Q, run ``pod_route``/``weighted_argmin`` over ONE workload
snapshot, then scatter the commits back into Q on the host.  Snapshot
routing has a correctness bug at bursty arrival rates — every arrival in
the batch sees the same argmin, so a burst herds onto one server in a way
the paper's sequential model (and GB-PANDAS) never does.

This kernel fuses all three stages into one launch and resolves conflicts
*inside* the batch: a W-delta accumulator lives in VMEM, and arrival b+1
scores against workloads that already include arrival b's commit
(``dW[sel] += inv_rates[sel, cls]`` per accepted arrival).  Semantics are
the paper's per-arrival sequential routing, at batched launch cost.

Two variants share the wrapper (``route_commit``):

  full  (``cls``: [B, M])           — Balanced-Pandas O(M) argmin per
        arrival over every server's weighted workload.
  pod   (``cand_idx``/``cand_cls``/``cand_valid``: [B, C]) — power-of-d
        argmin over an explicit candidate list (paper §IV-C); also serves
        JSQ-style shortest-queue routing with unit rates (queue length ==
        workload when every inverse rate is 1).

Tie-break contract (the in-kernel class-priority lane)
------------------------------------------------------
Exact score ties resolve by locality class first (LOCAL < RACK < REMOTE),
then — full variant — by an optional per-server integer priority ``prio``
(lower wins; pass a random permutation for the unbiased random ties the
sequential path and the event-accurate refsim use — W takes lattice
values, ties are ROUTINE, and always-lowest-index ties hotspot low-index
servers measurably), then by lowest server index.  The pod variant breaks
class ties by lowest candidate slot; slots are randomly sampled, so slot
order is already unbiased across slots.  The ranking is staged on
integers — ``rank = (cls * Mp + prio) * Mp + index`` under the tie mask —
so it is EXACT at any workload magnitude.  This replaces the old
host-side ``W + _BP_TIE_EPS`` uniform lift, which f32 addition silently
absorbed once W >> 1e-6 * ulp scale (W >~ 16), i.e. the documented class
tie-break did not fire at exactly the high loads where it matters.  If no
candidate has a finite score (all dead / invalid), the same ranking still
yields a deterministic pick (lowest class, then priority/index, valid
slots preferred) and the W commit is 0 (dead entries carry finite rate 0
in the encoding).

TPU mapping: one launch, whole operands VMEM-resident (the wrapper pads M
to 128 lanes, B and C to multiples of 8).  The heavy work — the initial
workload recompute ``W0 = sum(Q * inv)``, the pod candidate gather
(one-hot matmul, same formulation as pod_route), and the final Q scatter
(``one_hot(sel)^T @ one_hot(cls)`` on the MXU, same as queue_update) — is
batch-parallel; only the light argmin + rank-1 W-delta update runs in the
sequential ``fori_loop`` over arrivals.  VMEM high-water is the pod
variant's one-hot gather, ~B*C*Mp*4 bytes (B=64, C=16, M=8192 -> 32 MiB;
tile the batch on the host above that).

``interpret=None`` (default) auto-selects the Pallas interpreter off-TPU;
on a TPU backend the same call compiles to Mosaic.
Oracle: ref.route_commit_ref (exact sequential-commit semantics).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .invrates import FLAG_BASE, WIDTH, encode, resolve_interpret

LANE = 128
_BIG = 2**30  # tie-rank sentinel (fits int32; plain int so kernels close over no arrays)


def _class_select(sel_key, per_class):
    """per_class[c] broadcast-selected by ``sel_key`` in {0,1,2}."""
    return jnp.where(sel_key == 0, per_class[0],
                     jnp.where(sel_key == 1, per_class[1], per_class[2]))


def _commit_q(q, sel_v, cls_v, mask, b_pad, m_pad):
    """dQ = one_hot(sel)^T @ one_hot(cls) over accepted arrivals (the
    queue_update formulation — collision-free on the MXU)."""
    iota_bm = jax.lax.broadcasted_iota(jnp.int32, (b_pad, m_pad), 1)
    oh_sel = ((iota_bm == sel_v.reshape(b_pad, 1))
              & (mask.reshape(b_pad, 1) > 0)).astype(jnp.float32)
    iota_bc = jax.lax.broadcasted_iota(jnp.int32, (b_pad, 8), 1)
    oh_cls = (iota_bc == cls_v.reshape(b_pad, 1)).astype(jnp.float32)
    dq = jax.lax.dot_general(oh_sel, oh_cls, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return (q + dq).astype(jnp.int32)


def _kernel_full(q_ref, cls_ref, mask_ref, invm_ref, prio_ref,
                 qout_ref, wout_ref, sel_ref, selcls_ref, val_ref,
                 *, m_pad: int, b_pad: int):
    q = q_ref[...].astype(jnp.float32)           # [Mp, 8] (3 cols used)
    cls = cls_ref[...]                           # [Bp, Mp] (pad rows: 3)
    mask = mask_ref[...]                         # [1, Bp]  (commit gate)
    ir = invm_ref[...]                           # [Mp, 8] (see invrates)
    prio = prio_ref[...]                         # [1, Mp] tie priority < Mp

    # fused workload recompute: flag cols multiply the zero pad cols of q
    w0 = jnp.sum(q * ir, axis=1)[None, :]        # [1, Mp]
    rates = [ir[:, k][None, :] for k in range(3)]
    flags = [ir[:, FLAG_BASE + k][None, :] for k in range(3)]
    factor = _class_select(cls, rates)           # [Bp, Mp] finite 1/rate
    elig = ((cls < 3) & (_class_select(cls, flags) == 0.0)).astype(jnp.int32)

    rows = jax.lax.broadcasted_iota(jnp.int32, (b_pad, 1), 0)
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (1, m_pad), 1)
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (1, b_pad), 1)

    # arrivals past the last committing (valid) one never change dW — the
    # sequential loop only needs to run that far; everything after is one
    # vectorized tail pass against the final dW.  Typical batches are
    # Poisson draws far below the a_max padding, so this cuts the
    # sequential trip count from Bp to ~E[arrivals].
    n_proc = jnp.max(jnp.where(mask > 0, iota_b + 1, 0))

    def cond(carry):
        return carry[0] < n_proc

    def body(carry):
        b, dw, sel_v, cls_v, val_v = carry
        row = rows == b
        # mask-reduce row b out of the batch blocks (static shapes: no
        # dynamic slicing inside the loop)
        cls_b = jnp.sum(jnp.where(row, cls, 0), axis=0, keepdims=True)
        fac_b = jnp.sum(jnp.where(row, factor, 0.0), axis=0, keepdims=True)
        ok_b = jnp.sum(jnp.where(row, elig, 0), axis=0, keepdims=True) > 0
        # arrival b scores against W0 + the commits of arrivals 0..b-1
        scores = jnp.where(ok_b, (w0 + dw) * fac_b, jnp.inf)
        best = jnp.min(scores)
        # tie-break lane: class, then priority, then index — exact integer
        # ranking, no epsilon (fits int32: wrapper asserts 4*Mp^2 < _BIG)
        rank = jnp.where(scores == best,
                         (cls_b * m_pad + prio) * m_pad + iota_m, _BIG)
        rb = jnp.min(rank)
        sel = rb % m_pad
        scls = rb // (m_pad * m_pad)
        accept = jnp.sum(jnp.where(iota_b == b, mask, 0)) > 0
        # W-delta accumulator: the committed task adds 1/rate at (sel, cls)
        # (0 for a dead server — finite encoding carries 0 there)
        dw = dw + jnp.where((iota_m == sel) & accept, fac_b, 0.0)
        onb = iota_b == b
        return (b + 1, dw, jnp.where(onb, sel, sel_v),
                jnp.where(onb, scls, cls_v), jnp.where(onb, best, val_v))

    init = (jnp.int32(0),
            jnp.zeros((1, m_pad), jnp.float32),
            jnp.zeros((1, b_pad), jnp.int32),
            jnp.zeros((1, b_pad), jnp.int32),
            jnp.zeros((1, b_pad), jnp.float32))
    _, dw, sel_v, cls_v, val_v = jax.lax.while_loop(cond, body, init)

    # vectorized tail: arrivals b >= n_proc (all invalid) score against
    # the final dW — identical semantics to running the loop to Bp
    scores_t = jnp.where(elig > 0, (w0 + dw) * factor, jnp.inf)  # [Bp, Mp]
    best_t = jnp.min(scores_t, axis=1, keepdims=True)            # [Bp, 1]
    rank_t = jnp.where(scores_t == best_t,
                       (cls * m_pad + prio) * m_pad + iota_m, _BIG)
    rb_t = jnp.min(rank_t, axis=1, keepdims=True)
    done = iota_b < n_proc
    sel_v = jnp.where(done, sel_v, (rb_t % m_pad).reshape(1, b_pad))
    cls_v = jnp.where(done, cls_v,
                      (rb_t // (m_pad * m_pad)).reshape(1, b_pad))
    val_v = jnp.where(done, val_v, best_t.reshape(1, b_pad))

    qout_ref[...] = _commit_q(q, sel_v, cls_v, mask, b_pad, m_pad)
    wout_ref[...] = (w0 + dw).reshape(m_pad, 1)
    sel_ref[...] = sel_v
    selcls_ref[...] = cls_v
    val_ref[...] = val_v


def _kernel_pod(q_ref, idx_ref, cls_ref, cval_ref, mask_ref, invm_ref,
                qout_ref, wout_ref, sel_ref, selcls_ref, val_ref,
                *, m_pad: int, c_pad: int, b_pad: int, homogeneous: bool):
    q = q_ref[...].astype(jnp.float32)           # [Mp, 8]
    cand = idx_ref[...]                          # [Bp, Cp]
    ccls = cls_ref[...]                          # [Bp, Cp] (pad: 3)
    cval = cval_ref[...]                         # [Bp, Cp] int 0/1
    mask = mask_ref[...]                         # [1, Bp]
    ir = invm_ref[...]                           # [Mp, 8]

    w0 = jnp.sum(q * ir, axis=1)[None, :]        # [1, Mp]
    # candidate one-hot (the pod_route formulation): serves the workload
    # gathers (w0 + dW, fused into one dot each) and — heterogeneous
    # fleets only — the per-candidate rate/flag gather.
    flat = cand.reshape(b_pad * c_pad, 1)
    iota_mm = jax.lax.broadcasted_iota(jnp.int32, (b_pad * c_pad, m_pad), 1)
    onehot = (iota_mm == flat).astype(jnp.float32)           # [B*C, Mp]
    if homogeneous:
        # every row of ir is identical: the per-candidate rate is a pure
        # function of the class — no [B*C, Mp] x [Mp, 8] gather matmul
        factor = _class_select(ccls, [ir[0, 0], ir[0, 1], ir[0, 2]])
        dead = _class_select(ccls, [ir[0, FLAG_BASE], ir[0, FLAG_BASE + 1],
                                    ir[0, FLAG_BASE + 2]])
    else:
        irc = jax.lax.dot_general(onehot, ir, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        col = lambda k: irc[:, k].reshape(b_pad, c_pad)
        factor = _class_select(ccls, [col(0), col(1), col(2)])
        dead = _class_select(ccls, [col(FLAG_BASE), col(FLAG_BASE + 1),
                                    col(FLAG_BASE + 2)])
    elig = ((cval > 0) & (ccls < 3) & (dead == 0.0)).astype(jnp.int32)

    rows = jax.lax.broadcasted_iota(jnp.int32, (b_pad, 1), 0)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (1, c_pad), 1)
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (1, m_pad), 1)
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (1, b_pad), 1)
    iota_cm = jax.lax.broadcasted_iota(jnp.int32, (c_pad, m_pad), 1)

    # sequential work stops after the last valid arrival (see _kernel_full)
    n_proc = jnp.max(jnp.where(mask > 0, iota_b + 1, 0))

    def cond(carry):
        return carry[0] < n_proc

    def body(carry):
        b, dw, sel_v, cls_v, val_v = carry       # dw: [1, Mp]
        row = rows == b
        ccls_b = jnp.sum(jnp.where(row, ccls, 0), axis=0, keepdims=True)
        cand_b = jnp.sum(jnp.where(row, cand, 0), axis=0, keepdims=True)
        cval_b = jnp.sum(jnp.where(row, cval, 0), axis=0, keepdims=True)
        fac_b = jnp.sum(jnp.where(row, factor, 0.0), axis=0, keepdims=True)
        ok_b = jnp.sum(jnp.where(row, elig, 0), axis=0, keepdims=True) > 0
        # row-b candidate view of W0 + the intra-batch commits so far: one
        # small [Cp, Mp] one-hot gather (NOT the whole [B*C, Mp] block per
        # step, and w0 rides along in the same dot)
        oh_b = (iota_cm == cand_b.reshape(c_pad, 1)).astype(jnp.float32)
        wc_b = jax.lax.dot_general(
            oh_b, w0 + dw, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(1, c_pad)
        scores = jnp.where(ok_b, wc_b * fac_b, jnp.inf)
        best = jnp.min(scores)
        # class lane, then slot order; invalid slots only as a last resort
        rank = jnp.where(scores == best,
                         ccls_b * c_pad + iota_c + (1 - cval_b) * 4 * c_pad,
                         _BIG)
        slot = jnp.min(rank) % c_pad
        slot_oh = iota_c == slot
        sel = jnp.sum(jnp.where(slot_oh, cand_b, 0))
        scls = jnp.sum(jnp.where(slot_oh, ccls_b, 0))
        amt = jnp.sum(jnp.where(slot_oh, fac_b, 0.0))
        accept = jnp.sum(jnp.where(iota_b == b, mask, 0)) > 0
        dw = dw + jnp.where((iota_m == sel) & accept, amt, 0.0)
        onb = iota_b == b
        return (b + 1, dw, jnp.where(onb, sel, sel_v),
                jnp.where(onb, scls, cls_v), jnp.where(onb, best, val_v))

    init = (jnp.int32(0),
            jnp.zeros((1, m_pad), jnp.float32),
            jnp.zeros((1, b_pad), jnp.int32),
            jnp.zeros((1, b_pad), jnp.int32),
            jnp.zeros((1, b_pad), jnp.float32))
    _, dw, sel_v, cls_v, val_v = jax.lax.while_loop(cond, body, init)

    # vectorized tail for arrivals past the last valid one: all score
    # against the final dW (one whole-batch gather via the big one-hot)
    wc = jax.lax.dot_general(
        onehot, w0 + dw, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(b_pad, c_pad)
    scores_t = jnp.where(elig > 0, wc * factor, jnp.inf)
    best_t = jnp.min(scores_t, axis=1, keepdims=True)            # [Bp, 1]
    iota_bc = jax.lax.broadcasted_iota(jnp.int32, (b_pad, c_pad), 1)
    rank_t = jnp.where(scores_t == best_t,
                       ccls * c_pad + iota_bc + (1 - cval) * 4 * c_pad,
                       _BIG)
    slot_t = jnp.min(rank_t, axis=1, keepdims=True) % c_pad      # [Bp, 1]
    slot_oh_t = iota_bc == slot_t
    sel_t = jnp.sum(jnp.where(slot_oh_t, cand, 0), axis=1, keepdims=True)
    scls_t = jnp.sum(jnp.where(slot_oh_t, ccls, 0), axis=1, keepdims=True)
    done = iota_b < n_proc
    sel_v = jnp.where(done, sel_v, sel_t.reshape(1, b_pad))
    cls_v = jnp.where(done, cls_v, scls_t.reshape(1, b_pad))
    val_v = jnp.where(done, val_v, best_t.reshape(1, b_pad))

    qout_ref[...] = _commit_q(q, sel_v, cls_v, mask, b_pad, m_pad)
    wout_ref[...] = (w0 + dw).reshape(m_pad, 1)
    sel_ref[...] = sel_v
    selcls_ref[...] = cls_v
    val_ref[...] = val_v


def _pad_q(Q, Mp):
    return jnp.pad(Q.astype(jnp.int32), ((0, Mp - Q.shape[0]), (0, 5)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def route_commit(Q: jnp.ndarray, valid: jnp.ndarray, inv_rates: jnp.ndarray,
                 *, cls: Optional[jnp.ndarray] = None,
                 prio: Optional[jnp.ndarray] = None,
                 cand_idx: Optional[jnp.ndarray] = None,
                 cand_cls: Optional[jnp.ndarray] = None,
                 cand_valid: Optional[jnp.ndarray] = None,
                 interpret: Optional[bool] = None):
    """Fused sequential-commit routing of one arrival batch.

    Q: [M, 3] int32 sub-queue lengths; valid: [B] bool arrival/commit mask;
    inv_rates: [3] homogeneous or [M, 3] per-server (+inf = dead, masked
    to +inf scores after the multiply, never NaN).  Exactly one of:

      cls       [B, M] int32  — full variant (argmin over all M)
      cand_idx/cand_cls/cand_valid [B, C] — pod variant (candidate list)

    prio (full variant only): [M] int32 per-server tie priority in
    [0, M), lower wins after the class tie-break — pass a random
    permutation for unbiased ties (the sequential path / refsim
    semantics); None falls back to index order.

    Returns (Q_new [M, 3] int32, W_new [M] f32, sel [B] int32,
    sel_cls [B] int32, val [B] f32): the post-commit queues, the
    post-commit workloads as routing saw them (W0 + the sequential
    deltas), each arrival's chosen server + locality class, and its score
    at decision time.  Arrival b's score already reflects commits
    0..b-1 — see ref.route_commit_ref for the exact oracle.
    """
    M, three = Q.shape
    assert three == 3
    interp = resolve_interpret(interpret)
    # Mosaic needs 128-lane tiles; the interpreter (CPU/CI) has no lane
    # constraint, and at small M the 128-lane pad is ~3x wasted vector work
    # per slot.  Padding never changes results (pad lanes are ineligible
    # and the integer tie radix scales with Mp without reordering ranks).
    lane = LANE if not interp else 8
    Mp = max(8, -(-M // lane) * lane)
    assert 4 * Mp * Mp < _BIG, f"M={M}: tie-rank lane overflows int32"
    q_p = _pad_q(Q, Mp)
    invm = jnp.pad(encode(inv_rates, M), ((0, Mp - M), (0, 0)))  # [Mp, 8]

    if cls is not None:
        assert cand_idx is None, "pass cls OR cand_idx, not both"
        B = cls.shape[0]
        Bp = max(8, -(-B // 8) * 8)
        cls_p = jnp.pad(cls.astype(jnp.int32), ((0, Bp - B), (0, Mp - M)),
                        constant_values=3)
        mask_p = jnp.pad(valid.astype(jnp.int32), (0, Bp - B))[None, :]
        prio_p = jnp.arange(Mp, dtype=jnp.int32)   # pad lanes keep < Mp
        if prio is not None:
            prio_p = prio_p.at[:M].set(prio.astype(jnp.int32))
        kern = functools.partial(_kernel_full, m_pad=Mp, b_pad=Bp)
        operands = (q_p, cls_p, mask_p, invm, prio_p[None, :])
    else:
        assert cand_idx is not None and cand_cls is not None \
            and cand_valid is not None
        assert prio is None, "prio is a full-variant operand (slot order " \
            "is already random in a sampled candidate list)"
        B, C = cand_idx.shape
        Bp = max(8, -(-B // 8) * 8)
        Cp = max(8, -(-C // 8) * 8)
        pad2 = lambda x, fill: jnp.pad(x.astype(jnp.int32),
                                       ((0, Bp - B), (0, Cp - C)),
                                       constant_values=fill)
        mask_p = jnp.pad(valid.astype(jnp.int32), (0, Bp - B))[None, :]
        kern = functools.partial(_kernel_pod, m_pad=Mp, c_pad=Cp, b_pad=Bp,
                                 homogeneous=inv_rates.ndim == 1)
        operands = (q_p, pad2(cand_idx, 0), pad2(cand_cls, 3),
                    pad2(cand_valid, 0), mask_p, invm)

    q_new, w, sel, scls, val = pl.pallas_call(
        kern,
        out_shape=[
            jax.ShapeDtypeStruct((Mp, 8), jnp.int32),
            jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, Bp), jnp.int32),
            jax.ShapeDtypeStruct((1, Bp), jnp.int32),
            jax.ShapeDtypeStruct((1, Bp), jnp.float32),
        ],
        interpret=interp,
    )(*operands)
    return (q_new[:M, :3], w[:M, 0], sel[0, :B], scls[0, :B], val[0, :B])
