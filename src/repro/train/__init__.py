from .compression import EFQ, ef_decode, ef_encode, ring_allreduce_q8
from .pipeline import pipeline_forward
from .train_step import TrainState, init_train_state, loss_fn, train_step
from .trainer import Trainer, TrainerConfig
