"""Fault-tolerant training loop.

Responsibilities (the 1000-node story, exercised at laptop scale in tests):
  - jit the train step once; run the step loop with a checkpointable
    (params, opt, data-cursor) triple.
  - periodic async checkpoints; on start, auto-resume from the newest valid
    checkpoint (atomic manifests mean a crash mid-save is harmless).
  - deterministic resume: the data pipeline cursor is part of the
    checkpoint, so resumed training is bitwise-identical to uninterrupted
    training (tests/test_train.py::test_resume_bitwise).
  - failure injection hook (``fail_at_step``) for the recovery tests.
  - straggler telemetry: per-step wall time EMA; the shard re-balancer in
    repro.sched.straggler consumes it (and is itself the paper's scheduler).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint import checkpoint as ckpt
from ..data.pipeline import PipelineConfig, SyntheticLM
from ..optim.adamw import AdamWConfig
from .train_step import TrainState, init_train_state, train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    microbatches: int = 1
    grad_compress: bool = False
    seed: int = 0
    fail_at_step: Optional[int] = None     # failure injection (tests)
    async_ckpt: bool = True


class Trainer:
    def __init__(self, cfg, opt_cfg: AdamWConfig, tcfg: TrainerConfig,
                 pipeline: SyntheticLM,
                 log_fn: Callable[[str], None] = print):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.pipeline = pipeline
        self.log = log_fn
        self.step_times: list[float] = []

        self._step = jax.jit(functools.partial(
            train_step, cfg=cfg, opt_cfg=opt_cfg,
            microbatches=tcfg.microbatches,
            grad_compress=tcfg.grad_compress))

        key = jax.random.PRNGKey(tcfg.seed)
        self.state = init_train_state(cfg, opt_cfg, key)
        self.start_step = 0
        self._maybe_resume()

    # -- fault tolerance ----------------------------------------------------

    def _maybe_resume(self):
        latest = ckpt.restore_latest(self.tcfg.ckpt_dir,
                                     (self.state, {"step": 0, "seed": 0}))
        if latest is not None:
            step, (state, pipe_state), manifest = latest
            self.state = state
            self.pipeline.restore(jax.tree.map(
                lambda x: int(np.asarray(x)), pipe_state))
            self.start_step = step
            self.log(f"[trainer] resumed from checkpoint step {step}")

    def _save(self, step: int):
        pipe_state = {k: np.int64(v) for k, v in self.pipeline.state().items()}
        ckpt.save(self.tcfg.ckpt_dir, step, (self.state, pipe_state),
                  extra={"arch": self.cfg.name},
                  async_=self.tcfg.async_ckpt)

    # -- the loop -------------------------------------------------------------

    def run(self) -> dict:
        losses = []
        for step in range(self.start_step, self.tcfg.total_steps):
            if self.tcfg.fail_at_step is not None and step == self.tcfg.fail_at_step:
                ckpt.join_pending()
                raise RuntimeError(f"injected failure at step {step}")
            batch = self.pipeline.next_batch()
            t0 = time.perf_counter()
            self.state, metrics = self._step(self.state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            losses.append(loss)
            if step % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {step:5d} loss {loss:.4f} "
                         f"gnorm {float(metrics['grad_norm']):.3f} "
                         f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            if (step + 1) % self.tcfg.ckpt_every == 0 or \
                    step + 1 == self.tcfg.total_steps:
                self._save(step + 1)
        ckpt.join_pending()
        return {"losses": losses, "step_times": self.step_times}
