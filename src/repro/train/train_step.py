"""Training step: LM loss, grad accumulation (with optional error-feedback
int8 accumulator), AdamW update.  Designed to be jit/pjit'd whole: the
launcher lowers exactly this function for the dry-run cells.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import chunked_softmax_xent, forward
from ..optim.adamw import AdamWConfig, OptState, apply_update, init_opt_state
from .compression import ef_decode, ef_encode

F32 = jnp.float32

LB_COEF = 0.01      # MoE load-balance aux weight
Z_COEF = 1e-3       # router z-loss weight


class TrainState(NamedTuple):
    params: dict
    opt: OptState


def init_train_state(cfg, opt_cfg: AdamWConfig, key) -> TrainState:
    from ..models import init_params
    params = init_params(cfg, key)
    return TrainState(params=params, opt=init_opt_state(params, opt_cfg))


def loss_fn(params, cfg, batch, dispatch_groups: int = 1):
    h, aux = forward(params, cfg, batch, dispatch_groups=dispatch_groups)
    if cfg.family == "vlm":
        h = h[:, cfg.n_img_tokens:]          # loss over text positions only
    loss = chunked_softmax_xent(params["embed"], h, batch["labels"], cfg.vocab)
    total = loss + LB_COEF * aux["lb_loss"] + Z_COEF * aux["z_loss"]
    return total, {"loss": loss, **aux}


def _split_microbatches(batch: dict, n: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(f, batch)


def train_step(state: TrainState, batch: dict, *, cfg, opt_cfg: AdamWConfig,
               dispatch_groups: int = 1, microbatches: int = 1,
               grad_compress: bool = False, param_specs=None):
    """One optimizer step.  ``microbatches > 1`` accumulates gradients over
    sequential microbatches (activation-memory / global-batch decoupling);
    ``grad_compress`` stores the running accumulator in error-feedback int8
    (4x smaller accumulator — the residual carries quantization error into
    the next microbatch, preserving convergence; tests/test_train.py checks
    parity).

    ``param_specs`` (a PartitionSpec tree matching params) pins gradients
    and the accumulator to the parameter sharding: without it GSPMD may
    replicate ZeRO-sharded gradients and all-reduce full weight tensors
    (measured 2 x 4.26 GB f32 per layer-microbatch on kimi-k2; §Perf) —
    with it the DP sync lowers to the reduce-scatter ZeRO expects."""
    grad_of = jax.grad(functools.partial(loss_fn, cfg=cfg,
                                         dispatch_groups=dispatch_groups),
                       has_aux=True)

    def pin(tree):
        if param_specs is None:
            return tree
        def c(x, spec):
            try:
                return jax.lax.with_sharding_constraint(x, spec)
            except Exception:
                return x
        return jax.tree.map(c, tree, param_specs)

    if microbatches == 1:
        grads, aux = grad_of(state.params, batch=batch)
        grads = pin(grads)
    else:
        mb = _split_microbatches(batch, microbatches)

        is_efq = lambda x: hasattr(x, "q") and hasattr(x, "scale")

        def acc_step(carry, mb_i):
            acc, res = carry
            g, aux = grad_of(state.params, batch=mb_i)
            g = pin(g)
            if grad_compress:
                g = jax.tree.map(lambda a, b: a + b, g, res)
                enc = jax.tree.map(ef_encode, g)
                dec = jax.tree.map(ef_decode, enc, is_leaf=is_efq)
                res = jax.tree.map(lambda gg, d: gg - d, g, dec)
                g = dec
            acc = pin(jax.tree.map(lambda a, b: a + b.astype(F32), acc, g))
            return (acc, res), aux

        zeros = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, F32),
                                 state.params))
        (acc, _), auxs = jax.lax.scan(acc_step, (zeros, jax.tree.map(
            lambda p: jnp.zeros(p.shape, F32), state.params)), mb)
        grads = jax.tree.map(lambda a: a / microbatches, acc)
        aux = jax.tree.map(lambda x: x.mean(), auxs)

    params, opt, metrics = apply_update(state.params, grads, state.opt, opt_cfg)
    metrics.update(aux)
    return TrainState(params=params, opt=opt), metrics
