"""Gradient compression utilities.

Two levels (DESIGN.md §4):

1. ``ef_encode``/``ef_decode`` — error-feedback int8 block quantization of a
   gradient tree.  Used by train_step's microbatch accumulator; the
   quantization residual is carried into the next microbatch so the bias
   vanishes over steps (Seide et al. / EF-SGD).

2. ``ring_allreduce_q8`` — a shard_map ring all-reduce whose wire format is
   int8 (+ one f32 scale per chunk): reduce-scatter then all-gather, both
   phases moving int8 payloads via collective_permute.  On a real fleet this
   is the DCN-crossing (pod-axis) gradient sync at ~1/4 wire bytes; the s8
   collective-permutes are visible in lowered HLO, which is how the roofline
   collective term credits it.  Tested on a subprocess CPU mesh.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
_BLOCK = 256


class EFQ(NamedTuple):
    q: jnp.ndarray        # int8 blocks [n, _BLOCK]
    scale: jnp.ndarray    # f32 [n, 1]
    shape: tuple = ()
    size: int = 0


def ef_encode(x: jnp.ndarray) -> EFQ:
    flat = x.astype(F32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    s = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(s, 1e-20)).astype(jnp.int8)
    return EFQ(q=q, scale=s, shape=tuple(x.shape), size=x.size)


def ef_decode(t: EFQ) -> jnp.ndarray:
    flat = (t.q.astype(F32) * t.scale).reshape(-1)
    return flat[: t.size].reshape(t.shape)


jax.tree_util.register_pytree_node(
    EFQ,
    lambda t: ((t.q, t.scale), (t.shape, t.size)),
    lambda aux, ch: EFQ(q=ch[0], scale=ch[1], shape=aux[0], size=aux[1]),
)


# ---------------------------------------------------------------------------
# int8-wire ring all-reduce (shard_map collective)
# ---------------------------------------------------------------------------


def _q8(x):
    s = jnp.max(jnp.abs(x)) / 127.0
    q = jnp.round(x / jnp.maximum(s, 1e-20)).astype(jnp.int8)
    return q, s.reshape(1)


def _axis_size(axis_name: str) -> int:
    """Static mesh-axis size; jax.lax.axis_size only exists in newer jax."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    frame = jax.core.axis_frame(axis_name)
    return int(getattr(frame, "size", frame))


def ring_allreduce_q8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Sum ``x`` across ``axis_name`` with int8 wire format.

    Must be called inside shard_map with ``axis_name`` un-sharded in x
    (i.e. x is the local shard).  Quantization applies to the partial sums
    exchanged between neighbours (ring reduce-scatter, then ring
    all-gather of the final chunks).
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    size = x.size
    pad = (-size) % n
    flat = jnp.pad(x.astype(F32).reshape(-1), (0, pad)).reshape(n, -1)
    idx = jax.lax.axis_index(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def rs_step(k, acc):
        send_ix = (idx - k) % n
        payload = jax.lax.dynamic_index_in_dim(acc, send_ix, 0, keepdims=False)
        q, s = _q8(payload)
        q_r = jax.lax.ppermute(q, axis_name, fwd)
        s_r = jax.lax.ppermute(s, axis_name, fwd)
        recv_ix = (idx - k - 1) % n
        return jax.lax.dynamic_update_index_in_dim(
            acc, jax.lax.dynamic_index_in_dim(acc, recv_ix, 0, False)
            + q_r.astype(F32) * s_r, recv_ix, 0)

    acc = jax.lax.fori_loop(0, n - 1, rs_step, flat)

    # each rank now owns the fully-reduced chunk (idx + 1) % n
    def ag_step(k, acc):
        send_ix = (idx + 1 - k) % n
        payload = jax.lax.dynamic_index_in_dim(acc, send_ix, 0, keepdims=False)
        q, s = _q8(payload)
        q_r = jax.lax.ppermute(q, axis_name, fwd)
        s_r = jax.lax.ppermute(s, axis_name, fwd)
        recv_ix = (idx - k) % n
        return jax.lax.dynamic_update_index_in_dim(
            acc, q_r.astype(F32) * s_r, recv_ix, 0)

    acc = jax.lax.fori_loop(0, n - 1, ag_step, acc)
    return acc.reshape(-1)[:size].reshape(x.shape).astype(x.dtype)
