"""Pipeline parallelism over the pod axis (GPipe-style, shard_map).

On the multi-pod mesh the `pod` axis crosses DCN; instead of data-parallel
replication across pods, the layer stack can be SPLIT across pods (each pod
holds n_layers / n_stages layers) and microbatches stream through:

  stage s, step t processes microbatch (t - s); activations hop one pod per
  step over collective_permute.  Total steps = n_micro + n_stages - 1;
  bubble fraction = (n_stages - 1) / (n_micro + n_stages - 1).

This module implements the *forward* pipeline as a composable shard_map
program over stacked per-layer parameters (the same stacked pytrees the
model zoo uses).  It is exact: tests/test_distributed.py checks the
pipelined forward equals the sequential scan on a subprocess mesh.

Why GPipe (not 1F1B): with 2 pods the schedule difference is one
microbatch of bubble; the win here is the structure — per-pod weight
residency (half the params per pod) and DCN traffic = one [mb_tokens, d]
activation per step, which is what the multi-pod roofline needs priced.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(layer_fn: Callable, stacked_params, x: jnp.ndarray,
                     *, mesh, axis: str = "pod", n_micro: int = 4):
    """Run x through a layer stack split across ``axis``.

    layer_fn(params_slice, h) -> h          (one layer)
    stacked_params: pytree with leading dim n_layers (divisible by n_stages)
    x: [B, ...] activations (B divisible by n_micro)

    Returns the same result as scanning layer_fn over all layers.
    """
    n_stages = mesh.shape[axis]
    n_layers = jax.tree.leaves(stacked_params)[0].shape[0]
    assert n_layers % n_stages == 0
    per_stage = n_layers // n_stages
    B = x.shape[0]
    assert B % n_micro == 0

    # split layers across the pipeline axis: [n_layers,...] -> [n_stages*...]
    def split(p):
        return p.reshape(n_stages, per_stage, *p.shape[1:])
    staged = jax.tree.map(split, stacked_params)

    p_specs = jax.tree.map(lambda _: P(axis), staged)

    def stage_prog(params_local, xs):
        """Runs on one pod: params_local has leading dims [1, per_stage,...];
        xs [B, ...] (full batch, replicated input)."""
        params_local = jax.tree.map(lambda p: p[0], params_local)
        sid = jax.lax.axis_index(axis)
        mb = xs.reshape(n_micro, B // n_micro, *xs.shape[1:])
        steps = n_micro + n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def run_stage(h):
            def body(hh, pl):
                return layer_fn(pl, hh), None
            out, _ = jax.lax.scan(body, h, params_local)
            return out

        def step(carry, t):
            buf, out = carry                       # buf: incoming activation
            # stage s works on microbatch t - s when 0 <= t-s < n_micro
            m = t - sid
            active = (m >= 0) & (m < n_micro)
            inp = jnp.where(sid == 0,
                            mb[jnp.clip(m, 0, n_micro - 1)], buf)
            res = run_stage(inp)
            res = jnp.where(active, res, jnp.zeros_like(res))
            # last stage banks its finished microbatch
            out = jax.lax.cond(
                active & (sid == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, res, jnp.clip(m, 0, n_micro - 1), 0),
                lambda o: o, out)
            # hop activations to the next stage over the pod link
            buf = jax.lax.ppermute(res, axis, fwd)
            return (buf, out), None

        buf0 = jnp.zeros_like(mb[0])
        out0 = jnp.zeros_like(mb)
        (_, out), _ = jax.lax.scan(step, (buf0, out0), jnp.arange(steps))
        # every pod returns the same banked output (only the last stage
        # filled it) — broadcast via a masked psum.
        out = jax.lax.psum(
            jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out.reshape(B, *xs.shape[1:])

    prog = shard_map(stage_prog, mesh=mesh,
                     in_specs=(p_specs, P()), out_specs=P(),
                     check_rep=False)
    return prog(staged, x)
