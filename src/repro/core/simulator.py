"""Discrete-time slotted simulator for the paper's system model (§III).

Three queue-structure families cover the paper's six algorithms:

  BP family      (3 sub-queues per server: local / rack-local / remote)
      - balanced_pandas        routing: argmin weighted workload over all M
      - balanced_pandas_pod    routing: argmin over 3 locals + d sampled
      scheduling (both): serve own local queue, then rack-local, then remote.

  SQ family      (one queue per server; queued tasks are local to it)
      - jsq_maxweight          routing: shortest local queue (O(1) already);
                               scheduling: argmax over all M of
                               {alpha*Q_own, beta*Q_rack, gamma*Q_other}.
      - jsq_maxweight_pod      scheduling: argmax over own + d' sampled.
      - jsq_priority           scheduling: own queue first, else longest
                               queue in own rack, else longest anywhere.

  FCFS           (single central queue; idle servers grab the head task)

Time is slotted; service durations are sampled once at service start
(geometric == the paper's discrete-time model / memoryless; log-normal ==
the paper's heavy-tail simulations) and counted down.  Within a slot the
order is completions -> scheduling -> arrivals, and the task-in-system count
N is read at slot end, so Little's law (E[T] = E[N]/lambda) gives the mean
task completion time without per-task bookkeeping.  A numpy event-accurate
reference with per-task sojourns (refsim.py) validates this in tests.

Routing modes:
  sequential — each arrival sees the workload left by the previous one
               (faithful to the paper's per-arrival routing; inner scan of
               plain-JAX ops, random tie-breaks).
  batched    — the slot's whole arrival batch routes through ONE fused
               Pallas launch (kernels.route_commit): score -> route ->
               queue-commit with in-kernel sequential conflict resolution,
               so arrival b+1 scores against workloads that already
               include arrival b's commit (a W-delta accumulator in VMEM).
               This preserves the paper's per-arrival semantics — a burst
               spreads instead of herding onto one snapshot argmin — at
               one launch per slot; it is the same [M, 3]-rate MXU path
               the production PodRouter runs, traced inline into the
               jit'd step (interpret mode off-TPU).  Exact score ties
               resolve by locality class (LOCAL < RACK < REMOTE), then
               lowest server index / candidate slot — an exact integer
               rank lane in-kernel, valid at any workload magnitude —
               where the sequential path uses shared random priorities.
               The SQ family's batched routing rides the same kernel with
               unit rates (queue length == workload).

Scenarios (repro.scenarios): every run is parameterized by a ScenarioData
pytree — a [T] arrival-intensity shape, per-server speed multipliers with
time-indexed event windows, and optionally Zipf-skewed replica placement.
Speed is per locality CLASS: speed_t is an [M, 3] matrix (whole-server
events carry equal columns; per-class windows — network-tier degradation,
ToR cascades — scale beta/gamma independently).  Durations are sampled in
speed-1 work units at the class rate; a busy server completes
speed_t[m, c] units per slot for its in-flight class-c task, so a
straggler slows its in-flight task and a drained server (speed 0) freezes
and starts nothing — and a server whose beta tier is down can still start
local work.  The BP workload metric divides each sub-queue by the server's
own current [M, 3] rates, with drained (zero-rate) entries carried as
+inf inverse rates: they contribute 0 workload and score +inf in routing
(policies.weighted_score), so an empty dead server is never selected.
The default `uniform` scenario reproduces the symmetric model exactly.
For sweeps, ``simulate(..., pad=scenarios.canonical_pad(cluster),
a_max=scenarios.canonical_a_max(...))`` realizes every scenario to one
canonical pytree signature so the jit'd step compiles exactly once for the
whole registry (``trace_count`` instruments this; a regression test in
tests/test_scenarios.py guards it).

Scheduling is batched per slot: all idle servers act against the same
snapshot, with steal conflicts resolved by weight priority and queue-length
caps.  ``SimConfig.s_max`` bounds scheduling attempts per slot (capped
servers retry next slot); set s_max >= M for the exact uncapped dynamics
(tests do) — the default 64 only matters in transients where >64 servers
try to steal simultaneously.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .cluster import (
    GEOMETRIC,
    LOCAL,
    RACK,
    REMOTE,
    Cluster,
    Rates,
    inv_rate_matrix,
    locality_class,
    safe_inv_rates,
    sample_durations,
)
from ..kernels import ref as kernel_ref
from ..kernels import route_commit as kernel_route_commit
from ..telemetry import collectors as tlm
from ..scenarios.build import (
    ScenarioData,
    placement_epoch_at,
    realize,
    sample_locals_scenario,
    speed_at,
    stack_scenarios,
)
from ..scenarios.spec import get_scenario, scenario_names
from .policies import (
    PodSpec,
    bp_candidates_per_route,
    inv_rate_for,
    jsqmw_candidates_per_schedule,
    lex_argmax,
    lex_argmin,
    pod_candidates,
    route_balanced_pandas_full,
    route_jsq_local,
    route_pod_candidates,
    sample_rack_peer,
    sample_remote_peer,
    weighted_score,
)

_INF = jnp.inf


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulation parameters (hashable: safe as a jit static arg)."""

    T: int = 20_000               # total slots
    warmup: int = 4_000           # slots discarded before measuring
    a_max: int = 0                # max arrivals per slot (0 = auto from load)
    s_max: int = 64               # max scheduling attempts per slot
    route_mode: str = "sequential"  # "sequential" | "batched"
    service_dist: str = GEOMETRIC   # "geometric" | "lognormal"
    sigma: float = 1.0              # log-normal shape

    def resolve_a_max(self, lam: float, shape_peak: float = 1.0) -> int:
        """Arrival-buffer width from the PEAK slot intensity.

        ``lam`` is the mean arrival rate; ``shape_peak`` the maximum of the
        scenario's mean-1 intensity shape (flash / diurnal traces spike
        well above the mean — sizing the Poisson tail bound from the mean
        clips arrivals exactly in the scenarios the clip warnings exist
        for).  The bound is peak + 6*sqrt(peak) + 4: P(clip) per slot is
        ~1e-9 at the peak intensity.
        """
        if self.a_max > 0:
            return self.a_max
        import math
        peak = lam * shape_peak
        return int(math.ceil(peak + 6.0 * math.sqrt(peak) + 4))


class RawSums(NamedTuple):
    """Per-run accumulators."""

    slots: jnp.ndarray
    sum_N: jnp.ndarray
    sum_N_h1: jnp.ndarray
    sum_N_h2: jnp.ndarray
    arrivals: jnp.ndarray
    clipped: jnp.ndarray
    completions: jnp.ndarray
    starts: jnp.ndarray        # [3] service starts by locality class
    routed: jnp.ndarray        # [3] routing decisions by chosen class (BP family)
    busy: jnp.ndarray
    route_decisions: jnp.ndarray
    sched_decisions: jnp.ndarray
    final_N: jnp.ndarray

    @staticmethod
    def zero() -> "RawSums":
        """All-zero accumulator (scan carry init)."""
        z = jnp.float32(0.0)
        return RawSums(z, z, z, z, z, z, z, jnp.zeros(3, jnp.float32),
                       jnp.zeros(3, jnp.float32), z, z, z, z)


class SimResult(NamedTuple):
    """Per-run summary statistics (``summarize``); under ``simulate_grid``
    every leaf gains leading [seeds, loads] dims, under ``simulate_sweep``
    [scenarios, seeds, loads]."""
    mean_tasks_in_system: jnp.ndarray
    mean_completion_slots: jnp.ndarray
    mean_completion_norm: jnp.ndarray   # units of mean local service time
    arrival_rate_hat: jnp.ndarray
    throughput: jnp.ndarray
    utilization: jnp.ndarray
    locality_fractions: jnp.ndarray     # [3] of service starts
    routed_fractions: jnp.ndarray       # [3] of routing choices (BP family)
    drift: jnp.ndarray                  # mean_N(2nd half) / mean_N(1st half);
    #                                     NaN when the 1st half saw no mass
    #                                     (drift UNMEASURABLE — consumers must
    #                                     treat NaN as "not converged", never
    #                                     as "converged"; see telemetry.
    #                                     export.auto_extend_warmup)
    clip_fraction: jnp.ndarray
    route_decisions: jnp.ndarray
    sched_decisions: jnp.ndarray
    route_candidates_per_decision: jnp.ndarray
    sched_candidates_per_decision: jnp.ndarray


# ---------------------------------------------------------------------------
# Shared slot plumbing
# ---------------------------------------------------------------------------


def _speed_of_class(speed, cls):
    """[M] per-server speed for class ``cls[m]``; speed: [M, 3]."""
    return jnp.take_along_axis(speed, cls[:, None], axis=1)[:, 0]


def _progress_service(busy, rem, speed, cls, homo: bool = False):
    """Busy servers complete ``speed[m, cls[m]]`` work units this slot
    (cls = class of the in-flight task); rem is float32 work remaining.
    homo=True: speed is statically all-ones (no per-server gather).
    Return (busy', rem', completed_mask)."""
    rem = jnp.where(busy, rem - (1.0 if homo
                                 else _speed_of_class(speed, cls)), 0.0)
    completed = busy & (rem <= 0)
    busy = busy & ~completed
    rem = jnp.where(busy, rem, 0.0)
    return busy, rem, completed


def _arrival_batch(key, cluster, scen, lam_t, a_max, need_cls: bool, pe=0):
    """Poisson(lam_t) arrival count (clipped to a_max) + per-arrival
    locality under the scenario's placement law (``pe`` = the slot's
    churn-epoch index, see scenarios.placement_epoch_at)."""
    k_n, k_loc = jax.random.split(key)
    raw = jax.random.poisson(k_n, lam_t)
    n = jnp.minimum(raw, a_max)
    mask = jnp.arange(a_max) < n
    locals_ = sample_locals_scenario(k_loc, cluster, scen, a_max, pe=pe)
    cls = locality_class(cluster, locals_) if need_cls else None
    return mask, locals_, cls, (raw - n).astype(jnp.float32)


def _relation_rows(cluster: Cluster, rows: jnp.ndarray) -> jnp.ndarray:
    """[S, M] locality class of server rows[s] serving a task queued at
    (= local to) server n."""
    rack_of = cluster.rack_of
    n = jnp.arange(cluster.M, dtype=jnp.int32)
    same = rack_of[rows][:, None] == rack_of[None, :]
    own = rows[:, None] == n[None, :]
    return jnp.where(own, LOCAL, jnp.where(same, RACK, REMOTE)).astype(jnp.int32)


def _acc(sums: RawSums, *, in_half2, N, arr, clipped, comp, starts, routed,
         busy_n, routes, scheds, measure) -> RawSums:
    f = jnp.float32
    w = f(measure)
    return RawSums(
        slots=sums.slots + w,
        sum_N=sums.sum_N + w * N,
        sum_N_h1=sums.sum_N_h1 + w * N * (1.0 - f(in_half2)),
        sum_N_h2=sums.sum_N_h2 + w * N * f(in_half2),
        arrivals=sums.arrivals + w * arr,
        clipped=sums.clipped + w * clipped,
        completions=sums.completions + w * comp,
        starts=sums.starts + w * starts,
        routed=sums.routed + w * routed,
        busy=sums.busy + w * busy_n,
        route_decisions=sums.route_decisions + w * routes,
        sched_decisions=sums.sched_decisions + w * scheds,
        final_N=N,
    )


_SIZE_SALT = 7  # fold_in salt deriving the size-multiplier PRNG stream


def _task_work(key, dur, scen) -> jnp.ndarray:
    """Float32 work units for freshly started tasks: the sampled duration
    times the scenario's per-task size multiplier, exp(size_mu +
    size_sigma * z) — a mean-1 lognormal (realize sets mu = -sigma^2/2).
    size_sigma == 0 (every non-trace scenario) is the exact identity:
    the multiplier is exp(0.0) == 1.0 and the f32 product returns ``dur``
    bit-for-bit.  The normal draw comes from a salted fold of the duration
    key, so the legacy duration/arrival PRNG streams are untouched."""
    work = dur.astype(jnp.float32)
    if scen is None or scen.size_mu is None:
        return work
    z = jax.random.normal(jax.random.fold_in(key, _SIZE_SALT), work.shape)
    return work * jnp.exp(scen.size_mu + scen.size_sigma * z)


# ---------------------------------------------------------------------------
# BP family: Balanced-Pandas and Balanced-Pandas-Pod
# ---------------------------------------------------------------------------


class BPState(NamedTuple):
    """Balanced-Pandas family state: per-server 3-class sub-queues."""
    Q: jnp.ndarray          # int32 [M, 3] sub-queue lengths
    busy: jnp.ndarray       # bool  [M]
    rem: jnp.ndarray        # f32   [M] remaining service work units
    cls: jnp.ndarray        # int32 [M] class of in-service task

    @staticmethod
    def zero(M: int) -> "BPState":
        """Empty cluster of M servers."""
        return BPState(
            jnp.zeros((M, 3), jnp.int32), jnp.zeros(M, bool),
            jnp.zeros(M, jnp.float32), jnp.zeros(M, jnp.int32),
        )


def _bp_workload(Q: jnp.ndarray, inv_rates: jnp.ndarray) -> jnp.ndarray:
    """Paper §IV-A: W_m = Q^l/alpha_m + Q^k/beta_m + Q^r/gamma_m.

    inv_rates: [3] (homogeneous) or per-server [M, 3] (heterogeneous).
    Non-finite entries (drained servers, +inf inverse rate) contribute 0 —
    the queue_update kernel's semantics; routing masks dead servers by
    their rate (weighted_score), never by their W."""
    if inv_rates.ndim == 1:
        inv_rates = inv_rates[None, :]
    finite = jnp.where(jnp.isfinite(inv_rates), inv_rates, 0.0)
    return (Q.astype(jnp.float32) * finite).sum(axis=-1)


def _bp_schedule(key, Q, busy, rem, cls, rates, service_dist, sigma,
                 servable, scen=None):
    """Idle servers start their own head-of-class *servable* task:
    local > rack > remote among classes whose tier is up.  Purely local
    information — no cross-server messages (paper §IV-A).
    servable: bool [M, 3] (speed > 0) — a drained server starts nothing;
    a server whose beta tier is down skips rack-local work but still
    starts local/remote tasks.  None = statically all-servable (the
    homogeneous fast path).  Also returns (pick, start) so the
    telemetry sojourn ring can mirror the queue pops."""
    has = Q > 0 if servable is None else (Q > 0) & servable
    pick = jnp.argmax(has, axis=1).astype(jnp.int32)   # first servable class
    start = (~busy) & has.any(axis=1)
    Q = Q - (jax.nn.one_hot(pick, 3, dtype=jnp.int32) * start[:, None].astype(jnp.int32))
    dur = sample_durations(key, pick, rates, service_dist, sigma)
    busy = busy | start
    rem = jnp.where(start, _task_work(key, dur, scen), rem)
    cls = jnp.where(start, pick, cls)
    starts_by_class = (jax.nn.one_hot(pick, 3, dtype=jnp.float32)
                       * start[:, None].astype(jnp.float32)).sum(axis=0)
    return (Q, busy, rem, cls, starts_by_class,
            start.sum().astype(jnp.float32), pick, start)


def _full_bp_scores(W, cls_arr, inv_rates):
    """[..., M] weighted-workload score of EVERY server for each arrival —
    what the O(M) policy would examine (telemetry probe-quality oracle)."""
    m = jnp.arange(cls_arr.shape[-1], dtype=jnp.int32)
    return weighted_score(W, inv_rate_for(inv_rates, m, cls_arr))


def _bp_route_batch(key, cluster, Q, cls_arr, locals_, mask, inv_rates, pod,
                    sequential: bool, class_tiebreak: bool = True,
                    tcfg=None):
    """Route a slot's arrival batch; returns (Q', sel [A], sel_cls [A],
    probe) where probe = (rank_sum, regret_sum, n_decisions) telemetry
    (zeros when ``tcfg`` is None or probe collection is off).

    sequential: per-arrival plain-JAX routing, each arrival seeing the
    previous one's queues (the paper's model; random tie-breaks).
    batched: ONE fused kernels.route_commit launch — score, route, and
    queue-commit with in-kernel sequential conflict resolution, so each
    arrival still sees the previous one's commit (no snapshot herding).
    Exact ties break by locality class, then a per-slot random priority
    permutation (full BP; pod candidate slots are already randomly
    sampled), then index (class_tiebreak is a sequential-path knob; the
    kernel's class lane is always on).  Probe
    telemetry replays the evolving pre-commit workloads each arrival
    actually routed against (ref.route_commit_wseq), so batched probe
    ranks are measured against the same O(M) oracle the decision saw."""
    k_tie, k_pod, k_seq = jax.random.split(key, 3)
    tie_rnd = jax.random.uniform(k_tie, (cluster.M,))
    collect = tcfg is not None and tcfg.probes
    probe = tlm.ZERO_PROBE

    if sequential:
        def route_one(Qc, xs):
            cls_a, loc_a, valid, kr = xs
            W = _bp_workload(Qc, inv_rates)
            if pod is None:
                sel, sc = route_balanced_pandas_full(W, cls_a, inv_rates,
                                                     tie_rnd, class_tiebreak)
            else:
                kc, kt = jax.random.split(kr)
                ci, cc, cv = pod_candidates(kc, cluster, loc_a, cls_a, pod)
                sel, sc = route_pod_candidates(kt, W, ci, cc, cv, inv_rates)
            Qc = Qc.at[sel, sc].add(valid.astype(jnp.int32))
            if collect:
                full = _full_bp_scores(W, cls_a, inv_rates)
                return Qc, (sel, sc, full[sel], jnp.min(full),
                            (full < full[sel]).sum())
            return Qc, (sel, sc)
        keys = jax.random.split(k_seq, mask.shape[0])
        Q, ys = jax.lax.scan(route_one, Q, (cls_arr, locals_, mask, keys))
        if collect:
            sel, sel_cls, chosen, best, rank = ys
            regret = jnp.where(jnp.isfinite(chosen - best), chosen - best,
                               0.0)
            v = mask.astype(jnp.float32)
            probe = ((rank * v).sum(), (jnp.maximum(regret, 0.0) * v).sum(),
                     v.sum())
        else:
            sel, sel_cls = ys
    else:
        Q0 = Q
        if pod is None:
            # same tie semantics as the sequential path: class, then a
            # per-slot random priority (W is lattice-valued, ties are
            # routine; always-lowest-index ties hotspot low-index servers)
            Q, _W, sel, sel_cls, _val = kernel_route_commit(
                Q, mask, inv_rates, cls=cls_arr,
                prio=jax.random.permutation(k_tie, cluster.M))
        else:
            kc, _ = jax.random.split(k_pod)
            ci, cc, cv = pod_candidates(kc, cluster, locals_, cls_arr, pod)
            Q, _W, sel, sel_cls, _val = kernel_route_commit(
                Q, mask, inv_rates, cand_idx=ci, cand_cls=cc, cand_valid=cv)
        if collect:
            # rank each decision against the evolving O(M) oracle: the
            # pre-commit workload row arrival b actually routed against
            W_seq = kernel_ref.route_commit_wseq(Q0, sel, sel_cls, mask,
                                                 inv_rates)       # [A, M]
            full = _full_bp_scores(W_seq, cls_arr, inv_rates)
            chosen = jnp.take_along_axis(full, sel[:, None], axis=1)[:, 0]
            probe = tlm.probe_stats_min(full, chosen, mask)
    return Q, sel, sel_cls, probe


def _bp_step(state: BPState, sums: RawSums, key, *, cluster, rates, cfg,
             lam_t, scen, speed, inv_rate_m, pod, a_max, measure, in_half2,
             homo=False, class_tiebreak=True, t=None, tele=None, tcfg=None):
    k_sched, k_arr, k_route = jax.random.split(key, 3)

    busy, rem, completed = _progress_service(state.busy, state.rem, speed,
                                             state.cls, homo=homo)
    if tcfg is not None:
        # sojourn = completion slot - arrival slot of the in-service task
        tele = tlm.record_sojourns(tele, tcfg, t, cfg.warmup, completed)
    Q, busy, rem, cls_serv, starts, n_started, pick, start = _bp_schedule(
        k_sched, state.Q, busy, rem, state.cls, rates, cfg.service_dist,
        cfg.sigma, servable=None if homo else speed > 0, scen=scen)
    if tcfg is not None:
        m = jnp.arange(cluster.M, dtype=jnp.int32)
        tele = tlm.ring_pop(tele, tcfg, m * 3 + pick, start, m)

    mask, locals_, cls_arr, clipped = _arrival_batch(
        k_arr, cluster, scen, lam_t, a_max, need_cls=True,
        pe=placement_epoch_at(scen, t))
    Q, sel, sel_cls, probe = _bp_route_batch(
        k_route, cluster, Q, cls_arr, locals_, mask, inv_rate_m, pod,
        sequential=(cfg.route_mode == "sequential"),
        class_tiebreak=class_tiebreak, tcfg=tcfg)
    if tcfg is not None:
        tele = tlm.ring_push(tele, tcfg, sel * 3 + sel_cls, mask, t)

    routed = (jax.nn.one_hot(sel_cls, 3, dtype=jnp.float32)
              * mask[:, None].astype(jnp.float32)).sum(axis=0)

    N = Q.sum().astype(jnp.float32) + busy.sum().astype(jnp.float32)
    sums = _acc(sums, in_half2=in_half2, N=N,
                arr=mask.sum().astype(jnp.float32), clipped=clipped,
                comp=completed.sum().astype(jnp.float32), starts=starts,
                routed=routed, busy_n=busy.sum().astype(jnp.float32),
                routes=mask.sum().astype(jnp.float32), scheds=n_started,
                measure=measure)
    if tcfg is not None:
        tele = tlm.collect_step(
            tele, tcfg, t=t, T=cfg.T, N=N, q_mass=Q.sum(axis=0),
            qlen=Q.sum(axis=1), workload=_bp_workload(Q, inv_rate_m),
            arrivals=mask.sum(), clipped=clipped,
            completions=completed.sum(), busy_n=busy.sum(), probe=probe)
    return BPState(Q, busy, rem, cls_serv), sums, tele


# ---------------------------------------------------------------------------
# SQ family: JSQ-MaxWeight(-Pod) and JSQ-Priority
# ---------------------------------------------------------------------------


class SQState(NamedTuple):
    """JSQ family state: one scalar queue per server."""
    Q: jnp.ndarray          # int32 [M] queue lengths (tasks local to server)
    busy: jnp.ndarray
    rem: jnp.ndarray
    cls: jnp.ndarray

    @staticmethod
    def zero(M: int) -> "SQState":
        """Empty cluster of M servers."""
        return SQState(jnp.zeros(M, jnp.int32), jnp.zeros(M, bool),
                       jnp.zeros(M, jnp.float32), jnp.zeros(M, jnp.int32))


def _grant_conflicts(tgt, prio, has, Q, key, M):
    """Resolve batched steal conflicts among S claimants: at most Q[n] grants
    to queue n, higher-priority claimants first (prio = ascending-sort keys,
    random-uniform final tie-break).  Returns bool [S] granted.

    Claimant i is granted iff its priority rank among same-target claimants
    is below Q[tgt[i]].  The rank is a pairwise count — [S, S] staged
    lexicographic compares + a row sum — which is cheaper per slot than the
    old lexsort/searchsorted/scatter chain at scheduler batch sizes."""
    S = tgt.shape[0]
    rnd = jax.random.uniform(key, (S,))
    # beats[i, j]: claimant j precedes i in (prio..., rnd) ascending order
    beats = jnp.zeros((S, S), bool)
    eq = jnp.ones((S, S), bool)
    for k in tuple(prio) + (rnd,):
        beats = beats | (eq & (k[None, :] < k[:, None]))
        eq = eq & (k[None, :] == k[:, None])
    same = (tgt[None, :] == tgt[:, None]) & has[None, :] & has[:, None]
    rank = jnp.sum(same & beats, axis=1)
    return has & (rank < Q[tgt])


def _sq_schedule(key, cluster, Q, busy, rem, cls, rates, cfg, variant,
                 pod: Optional[PodSpec], speed, homo: bool = False,
                 tcfg=None, scen=None):
    """Batched scheduling for the single-queue family (see module docstring).

    variant: "maxweight" (argmax of rate-weighted queue lengths — the serving
    server's own per-class rates, so a fast server outbids a slow one for the
    same queue — over all M or over 1+d' Pod samples) or "priority" (own >
    longest-in-rack > longest-anywhere).  speed: [M, 3] current per-class
    multipliers; a (server, queue) pair whose locality-class tier is down
    (speed 0) is ineligible, and a fully drained server schedules nothing.
    homo=True asserts (statically — see _rates_homogeneous) that speed is
    identically 1, so the per-pair speed gathers and drain checks drop out
    of the slot loop with identical results.

    Also returns (rows, tgt, granted) for the telemetry sojourn rings and
    probe = (rank_sum, regret_sum, n) probe-quality stats: for the Pod
    variant the full [S, M] weight matrix the O(M) MaxWeight would have
    examined is recomputed and the pod pick ranked against it."""
    M = cluster.M
    S = min(cfg.s_max, M)
    k_rows, k_cand, k_tie, k_grant, k_dur = jax.random.split(key, 5)

    idle = ~busy
    anyq = (Q > 0).any()
    eligible = idle & ((Q > 0) | anyq)
    if not homo:
        eligible = eligible & (speed > 0).any(axis=1)
    if S == M:
        # every server is its own scheduling attempt: no subset to sample,
        # and row order is immaterial (grants tie-break on explicit rnd)
        rows = jnp.arange(M, dtype=jnp.int32)
    else:
        # up to S eligible servers (random priority; the rest retry next slot)
        rkey = jnp.where(eligible, jax.random.uniform(k_rows, (M,)), _INF)
        order = jnp.argsort(rkey)
        rows = order[:S]
    act = eligible if S == M else eligible[rows]

    collect = tcfg is not None and tcfg.probes
    probe = tlm.ZERO_PROBE
    qf = Q.astype(jnp.float32)
    if variant == "maxweight" and pod is None:
        rel = _relation_rows(cluster, rows)              # [S, M]
        if homo:
            w = qf[None, :] * rates.as_array()[rel]
            cand = jnp.broadcast_to((Q > 0)[None, :], (S, M))
        else:
            sp = speed[rows[:, None], rel]               # serving server's
            w = qf[None, :] * rates.as_array()[rel] * sp  # per-class speed
            cand = (Q > 0)[None, :] & (sp > 0)
        rnd = jax.random.uniform(k_tie, (S, M))
        tgt = lex_argmax(w, rnd, mask=cand)
        val = jnp.take_along_axis(w, tgt[:, None], axis=1)[:, 0]
        has = cand.any(axis=1) & act
        prio = (-val,)
        if collect:  # full MaxWeight = the O(M) oracle itself: rank 0
            probe = tlm.probe_stats_max(w, val, has, cand)
    elif variant == "maxweight":
        # one fused randint for the rack + remote probes (one PRNG sweep
        # per slot instead of two; same per-column uniform law)
        R = cluster.rack_size
        start = (rows // R) * R
        hi = jnp.concatenate([
            jnp.full((pod.d_rack,), max(R - 1, 1), jnp.int32),
            jnp.full((pod.d_remote,), max(M - R, 1), jnp.int32)])
        u = jax.random.randint(k_cand, (S, pod.d_rack + pod.d_remote), 0,
                               hi[None, :])
        x = u[:, :pod.d_rack]
        rack = start[:, None] + x + (x >= (rows - start)[:, None])
        y = u[:, pod.d_rack:]
        remote = y + jnp.where(y >= start[:, None], R, 0)
        cand_idx = jnp.concatenate([rows[:, None], rack, remote], axis=1)
        rel = jnp.concatenate([
            jnp.full((S, 1), LOCAL, jnp.int32),
            jnp.full((S, pod.d_rack), RACK, jnp.int32),
            jnp.full((S, pod.d_remote), REMOTE, jnp.int32)], axis=1)
        qc = Q[cand_idx]
        if homo:
            w = qc.astype(jnp.float32) * rates.as_array()[rel]
            cand = qc > 0
        else:
            sp = speed[rows[:, None], rel]
            w = qc.astype(jnp.float32) * rates.as_array()[rel] * sp
            cand = (qc > 0) & (sp > 0)
        rnd = jax.random.uniform(k_tie, cand_idx.shape)
        c = lex_argmax(w, rnd, mask=cand)
        tgt = jnp.take_along_axis(cand_idx, c[:, None], axis=1)[:, 0]
        val = jnp.take_along_axis(w, c[:, None], axis=1)[:, 0]
        has = cand.any(axis=1) & act
        prio = (-val,)
        if collect:  # rank the 1+d' pod pick against the full [S, M] oracle
            rel_f = _relation_rows(cluster, rows)
            sp_f = speed[rows[:, None], rel_f]
            w_f = qf[None, :] * rates.as_array()[rel_f] * sp_f
            elig = (Q > 0)[None, :] & (sp_f > 0)
            probe = tlm.probe_stats_max(w_f, val, has, elig)
    elif variant == "priority":
        rel = _relation_rows(cluster, rows)              # [S, M]
        if homo:
            nonempty = jnp.broadcast_to((Q > 0)[None, :], (S, M))
            own_has = Q[rows] > 0
        else:
            sp = speed[rows[:, None], rel]
            nonempty = (Q > 0)[None, :] & (sp > 0)
            own_has = (Q[rows] > 0) & (speed[rows, LOCAL] > 0)
        rack_set = (rel == RACK) & nonempty
        glob_set = (rel == REMOTE) & nonempty
        rnd = jax.random.uniform(k_tie, (S, M))
        wq = jnp.broadcast_to(qf[None, :], (S, M))
        rack_tgt = lex_argmax(wq, rnd, mask=rack_set)
        glob_tgt = lex_argmax(wq, rnd, mask=glob_set)
        rack_any = rack_set.any(axis=1)
        glob_any = glob_set.any(axis=1)
        tgt = jnp.where(own_has, rows,
                        jnp.where(rack_any, rack_tgt, glob_tgt))
        has = (own_has | rack_any | glob_any) & act
        class_rank = jnp.where(own_has, 0.0, jnp.where(rack_any, 1.0, 2.0))
        prio = (class_rank, -qf[tgt])
    else:
        raise ValueError(variant)

    granted = _grant_conflicts(tgt, prio, has, Q, k_grant, M)
    Q = Q.at[tgt].add(-granted.astype(jnp.int32))
    # locality class of (server rows[s], queue tgt[s]) — pairwise, O(S)
    rack_of = cluster.rack_of
    start_cls = jnp.where(rows == tgt, LOCAL,
                          jnp.where(rack_of[rows] == rack_of[tgt],
                                    RACK, REMOTE)).astype(jnp.int32)
    dur = sample_durations(k_dur, start_cls, rates, cfg.service_dist, cfg.sigma)
    work = _task_work(k_dur, dur, scen)

    if S == M:
        # rows == arange(M): the per-row scatters are identity placements
        busy = busy | granted
        rem = jnp.where(granted, work, rem)
        cls = jnp.where(granted, start_cls, cls)
    else:
        busy = busy.at[rows].set(busy[rows] | granted)
        rem = rem.at[rows].set(jnp.where(granted, work, rem[rows]))
        cls = cls.at[rows].set(jnp.where(granted, start_cls, cls[rows]))
    starts = (jax.nn.one_hot(start_cls, 3, dtype=jnp.float32)
              * granted[:, None].astype(jnp.float32)).sum(axis=0)
    n_dec = has.sum().astype(jnp.float32)
    return Q, busy, rem, cls, starts, n_dec, rows, tgt, granted, probe


def _sq_step(state: SQState, sums: RawSums, key, *, cluster, rates, cfg,
             lam_t, scen, speed, inv_rate_m, variant, pod, a_max, measure,
             in_half2, homo=False, t=None, tele=None, tcfg=None):
    k_sched, k_arr, k_route = jax.random.split(key, 3)

    busy, rem, completed = _progress_service(state.busy, state.rem, speed,
                                             state.cls, homo=homo)
    if tcfg is not None:
        tele = tlm.record_sojourns(tele, tcfg, t, cfg.warmup, completed)
    Q, busy, rem, cls_serv, starts, n_sched, rows, tgt, granted, probe = \
        _sq_schedule(k_sched, cluster, state.Q, busy, rem, state.cls, rates,
                     cfg, variant, pod, speed, homo=homo, tcfg=tcfg,
                     scen=scen)
    if tcfg is not None:
        tele = tlm.ring_pop(tele, tcfg, tgt, granted, rows)

    mask, locals_, _cls, clipped = _arrival_batch(
        k_arr, cluster, scen, lam_t, a_max, need_cls=False,
        pe=placement_epoch_at(scen, t))
    if cfg.route_mode == "sequential":
        def route_one(Qc, xs):
            loc, valid, kr = xs
            sel = route_jsq_local(kr, Qc, loc)
            return Qc.at[sel].add(valid.astype(jnp.int32)), sel
        keys = jax.random.split(k_route, a_max)
        Q, sel = jax.lax.scan(route_one, Q, (locals_, mask, keys))
    else:
        # fused route_commit with unit rates: queue length == workload, so
        # shortest-local-queue = the kernel's candidate argmin, and each
        # arrival sees the previous one's commit (no snapshot herding).
        # Ties break by replica slot order (vs the sequential path's
        # random pick) — a documented batched-mode contract difference.
        Q3 = jnp.zeros((cluster.M, 3), jnp.int32).at[:, 0].set(Q)
        Q3, _W, sel, _scls, _val = kernel_route_commit(
            Q3, mask, jnp.ones(3, jnp.float32), cand_idx=locals_,
            cand_cls=jnp.zeros_like(locals_),
            cand_valid=jnp.ones_like(locals_))
        Q = Q3[:, 0]
    if tcfg is not None:
        tele = tlm.ring_push(tele, tcfg, sel, mask, t)

    N = Q.sum().astype(jnp.float32) + busy.sum().astype(jnp.float32)
    sums = _acc(sums, in_half2=in_half2, N=N,
                arr=mask.sum().astype(jnp.float32), clipped=clipped,
                comp=completed.sum().astype(jnp.float32), starts=starts,
                routed=jnp.zeros(3, jnp.float32),
                busy_n=busy.sum().astype(jnp.float32),
                routes=mask.sum().astype(jnp.float32), scheds=n_sched,
                measure=measure)
    if tcfg is not None:
        # workload proxy: queued work at the local rate (JSQ queues are
        # local to their server); drained servers contribute 0
        inv_l = inv_rate_m[:, LOCAL] if inv_rate_m.ndim == 2 \
            else jnp.full((cluster.M,), inv_rate_m[LOCAL])
        inv_l = jnp.where(jnp.isfinite(inv_l), inv_l, 0.0)
        tele = tlm.collect_step(
            tele, tcfg, t=t, T=cfg.T, N=N,
            q_mass=jnp.stack([Q.sum().astype(jnp.float32),
                              jnp.float32(0.0), jnp.float32(0.0)]),
            qlen=Q, workload=Q.astype(jnp.float32) * inv_l,
            arrivals=mask.sum(), clipped=clipped,
            completions=completed.sum(), busy_n=busy.sum(), probe=probe)
    else:
        del inv_rate_m  # JSQ routing is workload-metric-free
    return SQState(Q, busy, rem, cls_serv), sums, tele


# ---------------------------------------------------------------------------
# FCFS: central queue, idle servers grab the head task
# ---------------------------------------------------------------------------


class FCFSState(NamedTuple):
    """FCFS state: a single central queue feeding all servers."""
    C: jnp.ndarray          # int32 scalar: central queue length
    busy: jnp.ndarray
    rem: jnp.ndarray
    cls: jnp.ndarray

    @staticmethod
    def zero(M: int) -> "FCFSState":
        """Empty cluster of M servers."""
        return FCFSState(jnp.zeros((), jnp.int32), jnp.zeros(M, bool),
                         jnp.zeros(M, jnp.float32), jnp.zeros(M, jnp.int32))


def _fcfs_step(state: FCFSState, sums: RawSums, key, *, cluster, rates, cfg,
               lam_t, scen, speed, inv_rate_m, a_max, measure, in_half2,
               homo=False, t=None, tele=None, tcfg=None):
    del inv_rate_m  # FCFS is workload-metric-free
    M = cluster.M
    G = min(cfg.s_max, M)
    k_rank, k_loc, k_dur, k_arr = jax.random.split(key, 4)

    busy, rem, completed = _progress_service(state.busy, state.rem, speed,
                                             state.cls, homo=homo)
    idle = ~busy if homo else (~busy) & (speed > 0).any(axis=1)
    r = jnp.where(idle, jax.random.uniform(k_rank, (M,)), _INF)
    rows = jnp.argsort(r)[:G]
    # locality of the grabbed task relative to the grabbing server: the task's
    # replica triple is iid (uniform or chunk-skewed) and independent of
    # everything else, so sampling it at dequeue time is distributionally
    # identical.
    pe = placement_epoch_at(scen, t)
    locals_g = sample_locals_scenario(k_loc, cluster, scen, G,
                                      pe=pe)  # [G, n_rep]
    rack_of = cluster.rack_of
    is_local = (locals_g == rows[:, None]).any(axis=1)
    in_rack = (rack_of[locals_g] == rack_of[rows][:, None]).any(axis=1)
    start_cls = jnp.where(is_local, LOCAL,
                          jnp.where(in_rack, RACK, REMOTE)).astype(jnp.int32)
    # a server whose tier for this task's class is down leaves it queued
    grant = (idle[rows] & (jnp.arange(G) < state.C)
             & (speed[rows, start_cls] > 0))
    dur = sample_durations(k_dur, start_cls, rates, cfg.service_dist, cfg.sigma)
    C = state.C - grant.sum().astype(jnp.int32)
    busy = busy.at[rows].set(busy[rows] | grant)
    rem = rem.at[rows].set(jnp.where(grant, _task_work(k_dur, dur, scen),
                                     rem[rows]))
    cls = state.cls.at[rows].set(jnp.where(grant, start_cls, state.cls[rows]))
    starts = (jax.nn.one_hot(start_cls, 3, dtype=jnp.float32)
              * grant[:, None].astype(jnp.float32)).sum(axis=0)

    mask, _, _, clipped = _arrival_batch(k_arr, cluster, scen, lam_t, a_max,
                                         need_cls=False, pe=pe)
    C = C + mask.sum().astype(jnp.int32)

    N = C.astype(jnp.float32) + busy.sum().astype(jnp.float32)
    sums = _acc(sums, in_half2=in_half2, N=N,
                arr=mask.sum().astype(jnp.float32), clipped=clipped,
                comp=completed.sum().astype(jnp.float32), starts=starts,
                routed=jnp.zeros(3, jnp.float32),
                busy_n=busy.sum().astype(jnp.float32),
                routes=jnp.float32(0.0), scheds=grant.sum().astype(jnp.float32),
                measure=measure)
    if tcfg is not None:
        # central queue: windows only — no per-task identity to ring-track
        tele = tlm.collect_step(
            tele, tcfg, t=t, T=cfg.T, N=N,
            q_mass=jnp.stack([C.astype(jnp.float32), jnp.float32(0.0),
                              jnp.float32(0.0)]),
            qlen=C[None].astype(jnp.float32), workload=None,
            arrivals=mask.sum(), clipped=clipped,
            completions=completed.sum(), busy_n=busy.sum(),
            probe=tlm.ZERO_PROBE)
    return FCFSState(C, busy, rem, cls), sums, tele


# ---------------------------------------------------------------------------
# Algorithm registry + entry point
# ---------------------------------------------------------------------------

# paper §V parameters: d = 8 = (2 rack-local + 6 remote) for BP-Pod routing;
# d' = 12 = (6 + 6) for JSQ-MW-Pod scheduling.
BP_POD_DEFAULT = PodSpec(d_rack=2, d_remote=6)
JSQMW_POD_DEFAULT = PodSpec(d_rack=6, d_remote=6)

ALGORITHMS = (
    "fcfs",
    "jsq_priority",
    "jsq_maxweight",
    "jsq_maxweight_pod",
    "balanced_pandas",
    "balanced_pandas_pod",
)


def _pod_for(algo: str, pod: Optional[PodSpec]) -> Optional[PodSpec]:
    if pod is not None:
        return pod
    if algo == "balanced_pandas_pod":
        return BP_POD_DEFAULT
    if algo == "jsq_maxweight_pod":
        return JSQMW_POD_DEFAULT
    return None


# -- jit trace-count instrumentation ----------------------------------------
# The body of a jit'd function executes (as Python) exactly once per compiled
# signature, so a plain counter bumped inside `_run` counts cache misses.
# The one-compile scenario sweep (canonical ScenarioData padding + shared
# a_max) is guarded by a regression test asserting this stays at 1 across
# the whole registry (tests/test_scenarios.py).

_TRACE_COUNTS: dict = {"_run": 0}


def trace_count() -> int:
    """Number of times the jit'd simulator step has been (re)traced."""
    return _TRACE_COUNTS["_run"]


def reset_trace_count() -> None:
    """Zero the ``_run`` retrace counter (test isolation helper)."""
    _TRACE_COUNTS["_run"] = 0


def _family(algo: str) -> str:
    if algo in ("balanced_pandas", "balanced_pandas_pod",
                "balanced_pandas_randomtie"):
        return "bp"
    if algo == "fcfs":
        return "fcfs"
    if algo in ("jsq_maxweight", "jsq_maxweight_pod", "jsq_priority"):
        return "sq"
    raise ValueError(f"unknown algorithm {algo!r}")


def _rates_homogeneous(scen: ScenarioData) -> bool:
    """Host-side static check: does this realized scenario leave every
    server at the symmetric base rates for the whole run?  True only for
    window-free realizations with unit base speeds — then the simulator can
    thread the homogeneous ``[3]`` inverse-rate vector instead of the
    ``[M, 3]`` matrix, and the route_commit kernel skips its per-candidate
    rate gather (statically, via ``ndim``).  Bit-identical either way:
    every consumer branches on ndim and a gather of identical rows returns
    exactly the shared row.  Canonically padded sweeps always carry window
    rows, so the one-compile contract is untouched (one signature, with
    this False)."""
    import numpy as _np
    return (scen.win_start.shape[0] == 0
            and bool(_np.all(_np.asarray(scen.base_speed) == 1.0)))


@functools.partial(
    jax.jit,
    static_argnames=("algo", "cluster", "rates", "cfg", "pod", "a_max",
                     "homo_rates", "tcfg"))
def _run(key, lam, scen: ScenarioData, *, algo: str, cluster: Cluster,
         rates: Rates, cfg: SimConfig, pod: Optional[PodSpec], a_max: int,
         homo_rates: bool = False, tcfg=None):
    _TRACE_COUNTS["_run"] += 1        # executes only on a jit cache miss
    half2_from = cfg.warmup + (cfg.T - cfg.warmup) // 2
    family = _family(algo)

    def step(carry, t):
        state, sums, tele = carry
        k = jax.random.fold_in(key, t)
        measure = t >= cfg.warmup
        in_half2 = t >= half2_from
        speed = speed_at(scen, t)                       # [M, 3] per-class
        kw = dict(cluster=cluster, rates=rates, cfg=cfg,
                  lam_t=lam * scen.lam_shape[t], scen=scen, speed=speed,
                  inv_rate_m=(safe_inv_rates(rates.as_array()) if homo_rates
                              else inv_rate_matrix(rates, speed)),
                  homo=homo_rates, a_max=a_max, measure=measure,
                  in_half2=in_half2, t=t, tele=tele, tcfg=tcfg)
        if family == "bp":
            state, sums, tele = _bp_step(
                state, sums, k, pod=pod,
                class_tiebreak=(algo != "balanced_pandas_randomtie"), **kw)
        elif family == "sq":
            variant = "priority" if algo == "jsq_priority" else "maxweight"
            state, sums, tele = _sq_step(state, sums, k, variant=variant,
                                         pod=pod, **kw)
        elif family == "fcfs":
            state, sums, tele = _fcfs_step(state, sums, k, **kw)
        else:
            raise ValueError(f"unknown algorithm {algo!r}")
        return (state, sums, tele), None

    if family == "bp":
        state0 = BPState.zero(cluster.M)
    elif family == "fcfs":
        state0 = FCFSState.zero(cluster.M)
    else:
        state0 = SQState.zero(cluster.M)
    tele0 = (tlm.zero_telemetry(tcfg, cluster.M, family)
             if tcfg is not None else None)

    (state, sums, tele), _ = jax.lax.scan(
        step, (state0, RawSums.zero(), tele0), jnp.arange(cfg.T))
    return sums, tele


def simulate(algo: str, cluster: Cluster, rates: Rates, load: float,
             key: jax.Array, cfg: SimConfig = SimConfig(),
             pod: Optional[PodSpec] = None, scenario=None,
             pad=None, a_max: Optional[int] = None) -> SimResult:
    """Run one simulation and return derived metrics.

    load: fraction of the (scenario-aware, time-averaged) capacity boundary;
    for the default `uniform` scenario that is lambda = load * M * alpha.
    scenario: a registered scenario name, a scenarios.Scenario, or None.
    pad / a_max: canonical sweep controls (scenarios.canonical_pad /
    scenarios.canonical_a_max) — realizing every scenario with the same pad
    and sharing one a_max keeps the whole sweep on a single compiled
    signature (see trace_count).
    """
    scen, lam_cap = realize(get_scenario(scenario), cluster, rates, cfg.T,
                            pad=pad)
    lam = float(load) * lam_cap
    pod = _pod_for(algo, pod)
    if a_max is None:
        a_max = cfg.resolve_a_max(lam, float(jnp.max(scen.lam_shape)))
    sums, _ = _run(key, jnp.float32(lam), scen, algo=algo, cluster=cluster,
                   rates=rates, cfg=cfg, pod=pod, a_max=a_max,
                   homo_rates=_rates_homogeneous(scen))
    return summarize(sums, algo, cluster, rates, pod)


def simulate_with_telemetry(
        algo: str, cluster: Cluster, rates: Rates, load: float,
        key: jax.Array, cfg: SimConfig = SimConfig(),
        pod: Optional[PodSpec] = None, scenario=None, pad=None,
        a_max: Optional[int] = None,
        telemetry: tlm.TelemetryConfig = tlm.TelemetryConfig()):
    """``simulate`` + in-jit collectors; returns (SimResult, Telemetry).

    The SimResult is bit-identical to ``simulate``'s (collectors never
    consume PRNG keys — tests/test_telemetry.py enforces it).  Host-side
    consumers live in repro.telemetry.export (JSONL events, windowed
    drift, sojourn percentiles, probe summaries)."""
    scen, lam_cap = realize(get_scenario(scenario), cluster, rates, cfg.T,
                            pad=pad)
    lam = float(load) * lam_cap
    pod = _pod_for(algo, pod)
    if a_max is None:
        a_max = cfg.resolve_a_max(lam, float(jnp.max(scen.lam_shape)))
    sums, tele = _run(key, jnp.float32(lam), scen, algo=algo,
                      cluster=cluster, rates=rates, cfg=cfg, pod=pod,
                      a_max=a_max, homo_rates=_rates_homogeneous(scen),
                      tcfg=telemetry)
    return summarize(sums, algo, cluster, rates, pod), tele


def simulate_auto_warmup(
        algo: str, cluster: Cluster, rates: Rates, load: float,
        key: jax.Array, cfg: SimConfig = SimConfig(),
        pod: Optional[PodSpec] = None, scenario=None, pad=None,
        a_max: Optional[int] = None,
        telemetry: tlm.TelemetryConfig = tlm.TelemetryConfig(),
        policy=None):
    """``simulate_with_telemetry`` + drift-aware auto-extend warmup.

    Runs ONCE at full ``cfg.T``, then lets
    ``telemetry.export.auto_extend_warmup`` push the measurement boundary
    forward window-by-window until the windowed drift of the surviving
    tail drops below ``policy.threshold`` (or the cap/min-tail guards
    fire).  Window sums are exact per-slot sums, so the re-derived tail
    statistics equal a run measured with the longer warmup — nothing is
    re-run or retraced (the one-compile sweep invariant holds; a
    fast-mixing run costs zero extensions).

    Returns ``(SimResult, Telemetry, WarmupReport)``.  The SimResult is
    the run's own (configured-warmup) summary — bit-identical to
    ``simulate_with_telemetry``; the report carries the realized warmup,
    convergence verdict, and the tail's mean_N / lam_hat /
    mean_completion / throughput.  A NaN drift is reported as NOT
    converged, loudly (see ``WarmupReport.note``)."""
    from ..telemetry.export import WarmupPolicy, auto_extend_warmup
    if policy is None:
        policy = WarmupPolicy()
    res, tele = simulate_with_telemetry(
        algo, cluster, rates, load, key, cfg=cfg, pod=pod,
        scenario=scenario, pad=pad, a_max=a_max, telemetry=telemetry)
    report = auto_extend_warmup(tele, telemetry, cfg.T, cfg.warmup,
                                policy=policy)
    return res, tele, report


def simulate_grid(algo: str, cluster: Cluster, rates: Rates, loads,
                  n_seeds: int, cfg: SimConfig = SimConfig(),
                  pod: Optional[PodSpec] = None, seed0: int = 0,
                  scenario=None, pad=None,
                  a_max: Optional[int] = None) -> SimResult:
    """Vectorized sweep: one compile, vmapped over loads x seeds.
    Returns SimResult with leading dims [n_seeds, n_loads].
    pad / a_max as in ``simulate``."""
    import numpy as _np
    scen, lam_cap = realize(get_scenario(scenario), cluster, rates, cfg.T,
                            pad=pad)
    lam = jnp.array([l * lam_cap for l in loads], jnp.float32)
    pod = _pod_for(algo, pod)
    if a_max is None:
        a_max = cfg.resolve_a_max(float(_np.max(_np.asarray(lam))),
                                  float(jnp.max(scen.lam_shape)))
    keys = jax.random.split(jax.random.PRNGKey(seed0), n_seeds)

    def one(key, l):
        sums, _ = _run(key, l, scen, algo=algo, cluster=cluster, rates=rates,
                       cfg=cfg, pod=pod, a_max=a_max,
                       homo_rates=_rates_homogeneous(scen))
        return sums

    sums = jax.vmap(lambda k: jax.vmap(lambda l: one(k, l))(lam))(keys)
    return summarize(sums, algo, cluster, rates, pod)


def simulate_grid_with_telemetry(
        algo: str, cluster: Cluster, rates: Rates, loads, n_seeds: int,
        cfg: SimConfig = SimConfig(), pod: Optional[PodSpec] = None,
        seed0: int = 0, scenario=None, pad=None,
        a_max: Optional[int] = None,
        telemetry: tlm.TelemetryConfig = tlm.TelemetryConfig()):
    """``simulate_grid`` + collectors; returns (SimResult, Telemetry) with
    leading dims [n_seeds, n_loads] on every leaf.  Aggregate over the
    batch axes with ``repro.telemetry.export.aggregate`` (sums add, maxima
    max), or index a single (seed, load) cell for per-run windows."""
    import numpy as _np
    scen, lam_cap = realize(get_scenario(scenario), cluster, rates, cfg.T,
                            pad=pad)
    lam = jnp.array([l * lam_cap for l in loads], jnp.float32)
    pod = _pod_for(algo, pod)
    if a_max is None:
        a_max = cfg.resolve_a_max(float(_np.max(_np.asarray(lam))),
                                  float(jnp.max(scen.lam_shape)))
    keys = jax.random.split(jax.random.PRNGKey(seed0), n_seeds)

    def one(key, l):
        return _run(key, l, scen, algo=algo, cluster=cluster, rates=rates,
                    cfg=cfg, pod=pod, a_max=a_max,
                    homo_rates=_rates_homogeneous(scen), tcfg=telemetry)

    sums, tele = jax.vmap(lambda k: jax.vmap(lambda l: one(k, l))(lam))(keys)
    return summarize(sums, algo, cluster, rates, pod), tele


# ---------------------------------------------------------------------------
# Batched mega-sweep: ONE compiled program per policy for the whole
# scenario x load x seed grid
# ---------------------------------------------------------------------------


def sweep_grid(cluster: Cluster, rates: Rates, cfg: SimConfig, loads,
               scenarios=None, pad=None, a_max: Optional[int] = None):
    """Host-side grid construction for ``simulate_sweep``.

    Realizes + stacks the scenarios (``scenarios.build.stack_scenarios``)
    and resolves the grid's shared arrival-buffer width.  Returns
    ``(names, stacked ScenarioData with leading [S], lam [S, L] float32
    absolute arrival rates, a_max)``.  ``scenarios`` is an iterable of
    registered names and/or Scenario objects (default: the full registry);
    ``a_max`` defaults to the maximum ``resolve_a_max`` over every
    (scenario, load) cell, sized from each scenario's peak slot intensity
    — one static width for the whole grid, so the grid shares one
    compiled signature.
    """
    import numpy as _np
    names = list(scenarios) if scenarios is not None \
        else list(scenario_names())
    stacked, caps = stack_scenarios(names, cluster, rates, cfg.T, pad=pad)
    loads = [float(l) for l in loads]
    lam = _np.asarray(caps)[:, None] * _np.asarray(loads)[None, :]
    if a_max is None:
        peaks = _np.max(_np.asarray(stacked.lam_shape), axis=1)
        a_max = max(cfg.resolve_a_max(float(c) * max(loads), float(p))
                    for c, p in zip(caps, peaks))
    labels = [getattr(n, "name", n) for n in names]
    return labels, stacked, jnp.asarray(lam, jnp.float32), int(a_max)


def _sweep_cells(keys, lam, scen, *, algo, cluster, rates, cfg, pod, a_max,
                 tcfg):
    """vmap the jit'd ``_run`` over the stacked grid.

    keys: [K] PRNG keys (one per Monte-Carlo seed, shared across cells the
    way ``simulate_grid`` shares them across loads); lam: [S, L]; scen:
    ScenarioData with leading [S].  Returns (sums, tele) with leading
    [S, K, L] on every leaf.  The jit boundary stays on ``_run``, so the
    whole grid lowers to ONE batched executable per policy signature and
    ``trace_count`` advances by exactly 1.
    """
    def one(key, l, sc):
        return _run(key, l, sc, algo=algo, cluster=cluster, rates=rates,
                    cfg=cfg, pod=pod, a_max=a_max, homo_rates=False,
                    tcfg=tcfg)

    def per_scen(lam_row, sc):
        def per_seed(k):
            return jax.vmap(lambda l: one(k, l, sc))(lam_row)
        return jax.vmap(per_seed)(keys)

    return jax.vmap(per_scen)(lam, scen)


def simulate_sweep(algo: str, cluster: Cluster, rates: Rates, loads,
                   n_seeds: int, cfg: SimConfig = SimConfig(),
                   pod: Optional[PodSpec] = None, seed0: int = 0,
                   scenarios=None, pad=None, a_max: Optional[int] = None,
                   telemetry=None, devices=None):
    """The whole scenario x load x seed grid as ONE program per policy.

    Stacks canonically-padded scenario pytrees along a leading axis
    (``scenarios.build.stack_scenarios``), vmaps the jit'd simulator over
    scenario x seed x load, and — when more than one device is visible —
    shard_maps the scenario axis across devices (single-device hosts, e.g.
    CPU CI, fall back to the plain vmap; pass ``devices`` to restrict the
    mesh).  The policy (``algo``, ``pod``) is a static branch: each policy
    is its own compiled program, and ``trace_count`` advances by exactly 1
    per policy for the entire grid (tests/test_sweep.py guards this).

    Per-cell PRNG: seed k of every (scenario, load) cell uses key
    ``jax.random.split(PRNGKey(seed0), n_seeds)[k]`` — exactly the keys
    ``simulate_grid`` uses, so each cell of the one-program sweep is
    BIT-IDENTICAL to the corresponding looped ``simulate_grid`` cell
    (also guarded by tests/test_sweep.py).

    Returns ``(names, SimResult, telemetry)`` where every SimResult leaf
    carries leading dims ``[n_scenarios, n_seeds, n_loads]`` and
    ``telemetry`` is None unless a TelemetryConfig is passed (then its
    leaves carry the same leading dims; reduce per cell with
    ``repro.telemetry.export.cell_view`` — never aggregate across cells).
    """
    import numpy as _np
    names, scen, lam, a_max = sweep_grid(cluster, rates, cfg, loads,
                                         scenarios=scenarios, pad=pad,
                                         a_max=a_max)
    pod = _pod_for(algo, pod)
    keys = jax.random.split(jax.random.PRNGKey(seed0), n_seeds)
    kw = dict(algo=algo, cluster=cluster, rates=rates, cfg=cfg, pod=pod,
              a_max=a_max, tcfg=telemetry)

    devs = list(devices) if devices is not None else jax.devices()
    S = lam.shape[0]
    D = min(len(devs), S)
    if D > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        pad_s = (-S) % D
        if pad_s:
            # repeat trailing scenarios so the scenario axis divides the
            # mesh evenly; the duplicate rows are dropped below
            rep = lambda x: jnp.concatenate([x, x[-pad_s:]], axis=0)
            scen = jax.tree_util.tree_map(rep, scen)
            lam = rep(lam)
        mesh = Mesh(_np.asarray(devs[:D]), ("scen",))
        fn = shard_map(
            lambda k, l, sc: _sweep_cells(k, l, sc, **kw), mesh=mesh,
            in_specs=(P(), P("scen"), P("scen")), out_specs=P("scen"),
            check_rep=False)
        sums, tele = fn(keys, lam, scen)
        if pad_s:
            drop = lambda x: x[:S]
            sums = jax.tree_util.tree_map(drop, sums)
            tele = jax.tree_util.tree_map(drop, tele)
    else:
        sums, tele = _sweep_cells(keys, lam, scen, **kw)
    return names, summarize(sums, algo, cluster, rates, pod), tele


def summarize(s: RawSums, algo: str, cluster: Cluster, rates: Rates,
              pod: Optional[PodSpec]) -> SimResult:
    """Reduce raw scan sums to a ``SimResult`` (Little's-law mean delay,
    locality fractions, drift, clip fraction, probe complexity)."""
    slots = jnp.maximum(s.slots, 1.0)
    mean_N = s.sum_N / slots
    lam_hat = s.arrivals / slots
    mean_T = mean_N / jnp.maximum(lam_hat, 1e-9)       # Little's law, slots
    h = jnp.maximum(slots / 2.0, 1.0)
    starts_total = jnp.maximum(s.starts.sum(-1, keepdims=True), 1.0)
    routed_total = jnp.maximum(s.routed.sum(-1, keepdims=True), 1.0)
    if algo in ("balanced_pandas", "balanced_pandas_pod",
                "balanced_pandas_randomtie"):
        route_cand = bp_candidates_per_route(cluster, pod)
        sched_cand = 1  # own sub-queues only — purely local information
    elif algo in ("jsq_maxweight", "jsq_maxweight_pod"):
        route_cand = cluster.n_replicas
        sched_cand = jsqmw_candidates_per_schedule(cluster, pod)
    elif algo == "jsq_priority":
        route_cand = cluster.n_replicas
        sched_cand = cluster.M
    else:  # fcfs
        route_cand = 0
        sched_cand = 1
    return SimResult(
        mean_tasks_in_system=mean_N,
        mean_completion_slots=mean_T,
        mean_completion_norm=mean_T * rates.alpha,
        arrival_rate_hat=lam_hat,
        throughput=s.completions / slots,
        utilization=s.busy / (slots * cluster.M),
        locality_fractions=s.starts / starts_total,
        routed_fractions=s.routed / routed_total,
        # NaN-explicit: an empty first half (e.g. warmup >= T, or a system
        # that never held a task) means drift is UNMEASURABLE — the old
        # 1e-9 guard silently turned that into a huge finite ratio that
        # drift<1.05 convergence checks mistook for "wildly diverging"
        # (or, with sum_N_h2 also 0, for a perfectly-converged 0/1e-9=0)
        drift=jnp.where(s.sum_N_h1 > 0,
                        (s.sum_N_h2 / h) / jnp.maximum(s.sum_N_h1 / h, 1e-30),
                        jnp.nan),
        clip_fraction=s.clipped / jnp.maximum(s.arrivals + s.clipped, 1.0),
        route_decisions=s.route_decisions,
        sched_decisions=s.sched_decisions,
        route_candidates_per_decision=jnp.float32(route_cand),
        sched_candidates_per_decision=jnp.float32(sched_cand),
    )
