"""Event-accurate numpy reference simulator (the oracle for tests).

Tracks every task individually (arrival slot -> service completion slot), so
mean completion time is measured directly per task rather than via Little's
law.  Deliberately simple and slow — plain Python over numpy state — and
structured exactly like the paper's §IV-A Balanced-Pandas(-Pod) description:
per-arrival routing, per-server FIFO sub-queues, local>rack>remote service.

tests/test_core.py checks that the vectorized JAX simulator's Little's-law
estimate agrees with this direct measurement within sampling error;
tests/test_scenarios.py does the same for a heterogeneous-fleet scenario
(per-server speeds — see ``simulate_bp_ref``'s ``speed`` parameter).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .cluster import Cluster, Rates

LOCAL, RACK, REMOTE = 0, 1, 2


@dataclasses.dataclass
class RefResult:
    """Summary of one event-accurate reference run (oracle for tests)."""
    mean_completion_slots: float
    mean_tasks_in_system: float
    n_completed: int
    locality_fractions: np.ndarray
    sojourns: np.ndarray | None = None   # exact per-task sojourn slots
    throughput: float = 0.0   # ALL completions per measured slot (incl.
    #                           pre-warmup arrivals) — in overload this
    #                           saturates at the capacity edge, the signal
    #                           the brute-force LP oracle probes


def _locality(cluster: Cluster, locals_: np.ndarray) -> np.ndarray:
    R = cluster.rack_size
    cls = np.full(cluster.M, REMOTE, np.int32)
    racks = np.unique(locals_ // R)
    for r in racks:
        cls[r * R:(r + 1) * R] = RACK
    cls[locals_] = LOCAL
    return cls


def simulate_bp_ref(cluster: Cluster, rates: Rates, load: float, T: int,
                    warmup: int, seed: int, d_rack: int = 0,
                    d_remote: int = 0, pod: bool = False,
                    speed: np.ndarray | None = None,
                    placement: tuple | None = None) -> RefResult:
    """Balanced-Pandas (pod=False) or Balanced-Pandas-Pod (pod=True).

    placement: optional ``(probs [C], locals [C, n_replicas])`` skewed
    catalog (the scenario engine's Zipf/adversarial placement axis): each
    arrival draws a chunk from ``probs`` and uses its fixed replica triple
    instead of sampling servers uniformly.  ``lam`` stays
    ``load * alpha * sum(local speed)`` — the FLEET edge — so probing
    ``load`` above the fluid-LP edge over-drives the system and the
    measured ``throughput`` saturates at the true (placement-aware)
    capacity: the brute-force oracle tests/test_capacity.py checks the LP
    against.  None keeps the historical uniform sampling bit-for-bit.

    speed: optional per-server speed multipliers (constant in time) — the
    heterogeneous-fleet model of repro.scenarios: [M] whole-server, or
    [M, 3] per locality class (per-tier degradation windows).  Durations
    are sampled in speed-1 work units at the class rate, a busy server m
    completes speed[m, c] units per slot for its in-flight class-c task,
    and the workload metric / routing scores use each server's own [M, 3]
    rates, with zero-rate entries carried as +inf inverse rates (the
    kernels' contract: 0 workload contribution, +inf routing score).
    None == all ones == the symmetric model.  The capacity edge matches
    the scenario engine: lam = load * alpha * sum(local speed)."""
    rng = np.random.default_rng(seed)
    M = cluster.M
    inv = 1.0 / np.array([rates.alpha, rates.beta, rates.gamma])
    if speed is None:
        speed = np.ones(M)
    speed = np.asarray(speed, np.float64)
    if speed.ndim == 1:
        speed = np.repeat(speed[:, None], 3, axis=1)
    # per-server reciprocal rates; +inf for drained (zero-rate) tiers
    inv_m = np.where(speed > 0, inv[None, :] / np.maximum(speed, 1e-12),
                     np.inf)
    inv_m_w = np.where(np.isfinite(inv_m), inv_m, 0.0)   # workload weights
    lam = load * rates.alpha * speed[:, 0].sum()

    queues = [[[], [], []] for _ in range(M)]   # arrival slots, FIFO
    Q = np.zeros((M, 3), np.int64)
    busy = np.zeros(M, bool)
    rem = np.zeros(M, np.float64)               # remaining work units
    serving_cls = np.zeros(M, np.int64)         # class of in-service task
    started_at = np.zeros(M, np.int64)          # arrival slot of in-service task
    sojourns: list[int] = []
    start_cls_counts = np.zeros(3, np.int64)
    sum_N = 0.0
    n_slots_measured = 0
    n_done_measured = 0
    if placement is not None:
        p_probs = np.asarray(placement[0], np.float64)
        p_probs = p_probs / p_probs.sum()
        p_locals = np.asarray(placement[1], np.int64)

    for t in range(T):
        # completions
        rem[busy] -= speed[np.arange(M), serving_cls][busy]
        done = busy & (rem <= 0)
        if t >= warmup:
            n_done_measured += int(done.sum())
        for m in np.where(done)[0]:
            if t >= warmup and started_at[m] >= warmup:
                sojourns.append(t - started_at[m])
        busy &= ~done

        # scheduling: own queues, first servable class local > rack > remote
        # (a drained tier is skipped; a fully drained server starts nothing)
        for m in np.where(~busy & (speed > 0).any(axis=1))[0]:
            for c in range(3):
                if queues[m][c] and speed[m, c] > 0:
                    arr_slot = queues[m][c].pop(0)
                    Q[m, c] -= 1
                    busy[m] = True
                    serving_cls[m] = c
                    started_at[m] = arr_slot
                    p = 1.0 / inv[c]
                    rem[m] = rng.geometric(p)
                    if t >= warmup:
                        start_cls_counts[c] += 1
                    break

        # arrivals
        for _ in range(rng.poisson(lam)):
            if placement is not None:
                locals_ = p_locals[rng.choice(len(p_probs), p=p_probs)]
            else:
                locals_ = rng.choice(M, size=cluster.n_replicas,
                                     replace=False)
            cls = _locality(cluster, locals_)
            W = (Q * inv_m_w).sum(axis=1)
            if pod:
                cand = list(locals_)
                rack_set = np.where(cls == RACK)[0]
                rem_set = np.where(cls == REMOTE)[0]
                if len(rack_set) and d_rack:
                    cand += list(rng.choice(rack_set, size=d_rack))
                if len(rem_set) and d_remote:
                    cand += list(rng.choice(rem_set, size=d_remote))
                cand = np.array(cand)
            else:
                cand = np.arange(M)
            ic = inv_m[cand, cls[cand]]
            # +inf contract: dead candidates score +inf after the multiply
            ww = np.where(np.isfinite(ic), W[cand] * ic, np.inf)
            # ties: faster class, then random
            best = ww.min()
            tied = cand[ww == best]
            tied = tied[cls[tied] == cls[tied].min()]
            m = rng.choice(tied)
            c = int(cls[m])
            queues[m][c].append(t)
            Q[m, c] += 1

        if t >= warmup:
            sum_N += Q.sum() + busy.sum()
            n_slots_measured += 1

    return RefResult(
        mean_completion_slots=float(np.mean(sojourns)) if sojourns else 0.0,
        mean_tasks_in_system=sum_N / max(n_slots_measured, 1),
        n_completed=len(sojourns),
        locality_fractions=start_cls_counts / max(start_cls_counts.sum(), 1),
        sojourns=np.asarray(sojourns, np.int64),
        throughput=n_done_measured / max(n_slots_measured, 1),
    )
