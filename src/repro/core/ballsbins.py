"""Classical balls-and-bins power-of-d experiment (paper §I).

Places n balls into n bins: d=1 (uniform random) gives max load
~ log n / log log n; d>=2 (choose the emptier of d sampled bins) gives
~ log log n / log d + O(1) — the exponential improvement that motivates the
paper.  Vectorized over balls via lax.scan; vmapped over seeds by callers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n", "d"))
def max_load(key: jax.Array, n: int, d: int) -> jnp.ndarray:
    """Max bin load after n balls -> n bins with d choices (d>=1)."""

    def place(loads, k):
        cand = jax.random.randint(k, (d,), 0, n)
        pick = cand[jnp.argmin(loads[cand])]
        return loads.at[pick].add(1), None

    keys = jax.random.split(key, n)
    loads, _ = jax.lax.scan(place, jnp.zeros(n, jnp.int32), keys)
    return loads.max()


def theory_d1(n: int) -> float:
    """~ log n / log log n."""
    import math
    return math.log(n) / math.log(math.log(n))


def theory_d(n: int, d: int) -> float:
    """~ log log n / log d."""
    import math
    return math.log(math.log(n)) / math.log(d)
