"""Routing / scheduling policies from the paper (§IV), as pure JAX functions.

Routing policies map (workloads, task locality, rng) -> chosen server.
Scheduling policies are embedded in the per-family simulators (simulator.py)
because they operate on the family's queue structure; this module provides
the shared primitives: exact lexicographic arg-min/max with masking, and
power-of-d candidate sampling.

Complexity accounting: the *simulation* of a policy is vectorized (that is
what makes it a JAX program), but the *algorithm's* message complexity — how
many queue-length/workload values the central scheduler must fetch per
decision — is the candidate-set size.  Each policy exposes
``candidates_per_decision`` so benchmarks report the paper's O(M) vs O(1)
comparison from first principles (paper §IV-C: (d+3)/M, 2.2% for M=500, d=8).

Sampling model: Pod candidates are drawn uniformly **with replacement** from
the rack-local / remote sets (the standard Mitzenmacher power-of-d model;
the collision probability for d=8 out of hundreds is <3% and only ever
*shrinks* the effective d, i.e. it is conservative for the paper's claims).
Draws use cumulative-count inversion (cumsum + searchsorted), which is O(M)
per task instead of the O(M log M) Gumbel-top-k a without-replacement draw
would need — this is the simulator's innermost loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .cluster import LOCAL, RACK, REMOTE, Cluster, locality_class

_INF = jnp.inf


def lex_argmin(values: jnp.ndarray, *tiebreaks: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Exact staged arg-min: minimize ``values`` over ``mask``; break ties by
    each ``tiebreaks`` array in turn (lower wins); final ties -> lowest index.

    Exact (no epsilon hacks): comparisons are staged, so float resolution
    never mixes keys.  Inputs [..., M]; reduction over the last axis.
    """
    v = jnp.where(mask, values, _INF)
    best = jnp.min(v, axis=-1, keepdims=True)
    tie = (v == best) & mask
    for tb in tiebreaks:
        t = jnp.where(tie, tb, _INF)
        tbest = jnp.min(t, axis=-1, keepdims=True)
        tie = tie & (t == tbest)
    return jnp.argmax(tie, axis=-1).astype(jnp.int32)


def lex_argmax(values: jnp.ndarray, *tiebreaks: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """``lex_argmin`` on negated values: masked argmax with tie lanes."""
    return lex_argmin(-values, *tiebreaks, mask=mask)


def masked_draws(key: jax.Array, set_mask: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """k uniform-with-replacement draws from each row of ``set_mask``.

    set_mask: bool [..., M].  Returns (idx int32 [..., k], valid bool [..., k]);
    rows with an empty set yield valid=False.  Inversion sampling: the
    (u+1)-th set member is the first index where cumsum(mask) > u.
    """
    csum = jnp.cumsum(set_mask.astype(jnp.int32), axis=-1)
    total = csum[..., -1]
    u = jax.random.randint(key, set_mask.shape[:-1] + (k,), 0,
                           jnp.maximum(total, 1)[..., None])
    # searchsorted(csum, u, 'right') == #(csum <= u): one fused counting op
    # over [..., k, M] instead of a vmapped binary search (hot path: every
    # pod-candidate draw, every slot)
    idx = jnp.sum((csum[..., None, :] <= u[..., :, None]).astype(jnp.int32),
                  axis=-1)
    valid = jnp.broadcast_to((total > 0)[..., None], idx.shape)
    return jnp.minimum(idx, set_mask.shape[-1] - 1), valid


def weighted_score(W: jnp.ndarray, inv: jnp.ndarray) -> jnp.ndarray:
    """``W * inv`` under the +inf zero-rate contract (kernels/invrates.py):
    a non-finite inverse rate (drained / failed server) scores ``+inf``
    AFTER the multiply — never ``0 * inf = NaN``, and never the 0 a finite
    sentinel produced for an empty dead server (which then absorbed one
    task per outage window).  Mirrors the kernels' dead-flag mask."""
    return jnp.where(jnp.isfinite(inv), W * inv, jnp.inf)


def inv_rate_for(inv_rates: jnp.ndarray, idx: jnp.ndarray,
                 cls: jnp.ndarray) -> jnp.ndarray:
    """Reciprocal service rate of server ``idx`` for a task of class ``cls``.

    inv_rates is either the homogeneous [3] vector (every server identical —
    the seed model) or a per-server [M, 3] matrix (heterogeneous fleets,
    scenarios); the two forms are distinguished statically by ndim so jit
    traces stay branch-free.  idx/cls broadcast together.
    """
    if inv_rates.ndim == 1:
        return inv_rates[cls]
    return inv_rates[idx, cls]


@dataclasses.dataclass(frozen=True)
class PodSpec:
    """Power-of-d sampling spec: how many rack-local / remote servers to probe
    in addition to the task's local servers.  The paper's §V uses d=8 split as
    (2 rack-local, 6 remote) for Balanced-Pandas-Pod and d'=12 as (6, 6) for
    JSQ-MaxWeight-Pod scheduling."""

    d_rack: int
    d_remote: int

    @property
    def d(self) -> int:
        """Total probe budget (rack + remote candidates)."""
        return self.d_rack + self.d_remote


def pod_candidates(
    key: jax.Array,
    cluster: Cluster,
    locals_: jnp.ndarray,
    cls: jnp.ndarray,
    pod: PodSpec,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Candidate lists for Balanced-Pandas-Pod routing.

    locals_: int32 [..., n_rep]; cls: int32 [..., M] locality classes.
    Returns (cand_idx, cand_cls, valid), each [..., C] with
    C = n_rep + d_rack + d_remote, ordered [locals | rack draws | remote
    draws] so that first-index tie-breaking prefers faster classes — the
    ordering the paper's ArgMin notation implies.
    """
    k_rack, k_rem = jax.random.split(key)
    n_rep = locals_.shape[-1]
    rack_idx, rack_ok = masked_draws(k_rack, cls == RACK, pod.d_rack)
    rem_idx, rem_ok = masked_draws(k_rem, cls == REMOTE, pod.d_remote)
    cand_idx = jnp.concatenate([locals_, rack_idx, rem_idx], axis=-1)
    shp = locals_.shape[:-1]
    cand_cls = jnp.concatenate([
        jnp.broadcast_to(jnp.int32(LOCAL), shp + (n_rep,)),
        jnp.broadcast_to(jnp.int32(RACK), shp + (pod.d_rack,)),
        jnp.broadcast_to(jnp.int32(REMOTE), shp + (pod.d_remote,)),
    ], axis=-1)
    valid = jnp.concatenate(
        [jnp.ones(shp + (n_rep,), bool), rack_ok, rem_ok], axis=-1)
    return cand_idx, cand_cls, valid


def route_pod_candidates(
    key: jax.Array,
    W: jnp.ndarray,
    cand_idx: jnp.ndarray,
    cand_cls: jnp.ndarray,
    valid: jnp.ndarray,
    inv_rates: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Argmin of weighted workload over an explicit candidate list.

    Semantics shared with kernels/pod_route.py (which accelerates exactly
    this on TPU).  Ties: faster class first (candidate ordering), then
    uniformly at random.  Returns (server, class) for each task.
    inv_rates: [3] or per-server [M, 3] (see inv_rate_for).
    """
    scores = weighted_score(W[cand_idx],
                            inv_rate_for(inv_rates, cand_idx, cand_cls))
    rnd = jax.random.uniform(key, cand_idx.shape)
    c = lex_argmin(scores, cand_cls.astype(jnp.float32), rnd, mask=valid)
    sel = jnp.take_along_axis(cand_idx, c[..., None], axis=-1)[..., 0]
    sel_cls = jnp.take_along_axis(cand_cls, c[..., None], axis=-1)[..., 0]
    return sel, sel_cls


def route_balanced_pandas_full(
    W: jnp.ndarray,
    cls: jnp.ndarray,
    inv_rates: jnp.ndarray,
    tie_rnd: jnp.ndarray,
    class_tiebreak: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced-Pandas O(M) routing: argmin over all M of the weighted
    workload (paper §IV-A).  Ties -> faster class (the ArgMin term ordering;
    class_tiebreak=False ablates to uniform-random ties — the variant that
    reproduces the paper's BP-Pod>BP medium-load ordering, see EXPERIMENTS
    §Paper-claims), then ``tie_rnd`` (a [M] random priority, shared within a
    slot — unbiased across slots).  inv_rates: [3] or per-server [M, 3]."""
    m = jnp.arange(cls.shape[-1], dtype=jnp.int32)
    ww = weighted_score(W, inv_rate_for(inv_rates, m, cls))
    mask = jnp.ones(cls.shape, bool)
    keys = ((cls.astype(jnp.float32),) if class_tiebreak else ())
    sel = lex_argmin(ww, *keys,
                     jnp.broadcast_to(tie_rnd, cls.shape), mask=mask)
    sel_cls = jnp.take_along_axis(cls, sel[..., None], axis=-1)[..., 0]
    return sel, sel_cls


def route_jsq_local(
    key: jax.Array,
    Q: jnp.ndarray,
    locals_: jnp.ndarray,
) -> jnp.ndarray:
    """JSQ-MaxWeight(-Pod) / JSQ-Priority routing: join the shortest *local*
    queue (paper §IV-B).  Q: [M]; locals_: int32 [..., R].  Already O(1):
    only the n_replicas local queues are examined."""
    qloc = Q[locals_]
    rnd = jax.random.uniform(key, locals_.shape)
    mask = jnp.ones(locals_.shape, dtype=bool)
    pick = lex_argmin(qloc.astype(jnp.float32), rnd, mask=mask)
    return jnp.take_along_axis(locals_, pick[..., None], axis=-1)[..., 0]


# ----------------------------------------------------------------------------
# O(1) in-rack / out-of-rack draws (server ids are contiguous by rack, so both
# sets are index intervals — no cumsum needed).  Used by JSQ-MW-Pod scheduling.
# ----------------------------------------------------------------------------


def sample_rack_peer(key: jax.Array, cluster: Cluster, server: jnp.ndarray,
                     k: int) -> jnp.ndarray:
    """k uniform draws (with replacement) from ``server``'s rack, excluding
    itself.  server: int32 [...]; returns int32 [..., k]."""
    R = cluster.rack_size
    start = (server // R) * R
    off = server - start
    x = jax.random.randint(key, server.shape + (k,), 0, max(R - 1, 1))
    x = x + (x >= off[..., None])
    return start[..., None] + x


def sample_remote_peer(key: jax.Array, cluster: Cluster, server: jnp.ndarray,
                       k: int) -> jnp.ndarray:
    """k uniform draws (with replacement) from outside ``server``'s rack."""
    R = cluster.rack_size
    start = (server // R) * R
    u = jax.random.randint(key, server.shape + (k,), 0, max(cluster.M - R, 1))
    return u + jnp.where(u >= start[..., None], R, 0)


# ----------------------------------------------------------------------------
# Message/complexity accounting (paper §IV-C / abstract): values the central
# scheduler must fetch per decision.
# ----------------------------------------------------------------------------


def bp_candidates_per_route(cluster: Cluster, pod: Optional[PodSpec]) -> int:
    """Servers BP(-Pod) scores per routing decision (complexity table)."""
    if pod is None:
        return cluster.M
    return cluster.n_replicas + pod.d


def jsqmw_candidates_per_schedule(cluster: Cluster, pod: Optional[PodSpec]) -> int:
    """Queues JSQ-MW(-Pod) scans per scheduling decision."""
    if pod is None:
        return cluster.M
    return 1 + pod.d
