"""Core library: the paper's scheduling algorithms + slotted JAX simulator."""
from .cluster import (
    GEOMETRIC,
    LOCAL,
    LOGNORMAL,
    RACK,
    REMOTE,
    Cluster,
    Rates,
    capacity_arrival_rate,
    inv_rate_matrix,
    locality_class,
    rate_matrix,
    safe_inv_rates,
    sample_durations,
    sample_locals,
)
from .policies import (
    PodSpec,
    bp_candidates_per_route,
    inv_rate_for,
    jsqmw_candidates_per_schedule,
    lex_argmax,
    lex_argmin,
    masked_draws,
    pod_candidates,
    route_balanced_pandas_full,
    route_jsq_local,
    route_pod_candidates,
    sample_rack_peer,
    sample_remote_peer,
    weighted_score,
)
from .simulator import (
    ALGORITHMS,
    BP_POD_DEFAULT,
    JSQMW_POD_DEFAULT,
    BPState,
    FCFSState,
    SimConfig,
    SimResult,
    SQState,
    reset_trace_count,
    simulate,
    simulate_grid,
    trace_count,
)

__all__ = [n for n in dir() if not n.startswith("_")]
