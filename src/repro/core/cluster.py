"""Cluster topology: M servers in K equal racks, 3-level data locality.

This mirrors the paper's §III system model.  A task's data chunk lives on
``n_replicas`` (default 3, the Hadoop default) "local" servers.  Servers that
share a rack with a local server are "rack-local"; everything else is
"remote".  Service durations are geometric (the paper's discrete-time model,
the memoryless analogue of exponential) or discretized log-normal (the
paper's heavy-tail simulation), with per-slot rates alpha > beta > gamma.

On a TPU fleet the same three levels are: HBM-resident state (local),
same-pod fetch over ICI (rack-local), cross-pod fetch over DCN (remote) —
see DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

LOCAL, RACK, REMOTE = 0, 1, 2


class Rates(NamedTuple):
    """Per-slot service completion probabilities (local, rack-local, remote)."""

    alpha: float = 0.04
    beta: float = 0.02
    gamma: float = 0.008

    def as_array(self) -> jnp.ndarray:
        """[3] float32 (alpha, beta, gamma) for vectorized rate lookups."""
        return jnp.array([self.alpha, self.beta, self.gamma], dtype=jnp.float32)

    def mean_slots(self) -> jnp.ndarray:
        """[3] mean service slots per locality class (1 / rate)."""
        return 1.0 / self.as_array()


@dataclasses.dataclass(frozen=True)
class Cluster:
    """Static cluster topology.  All fields are Python ints / tuples so the
    object can be closed over by ``jax.jit`` without retracing hazards."""

    M: int  # number of servers
    K: int  # number of racks (M % K == 0)
    n_replicas: int = 3  # local servers per task (Hadoop default)

    def __post_init__(self):
        if self.M % self.K != 0:
            raise ValueError(f"M={self.M} must be divisible by K={self.K}")
        if self.n_replicas >= self.M:
            raise ValueError("need n_replicas < M")

    @property
    def rack_size(self) -> int:
        """Servers per rack (M / K; checked divisible)."""
        return self.M // self.K

    @property
    def rack_of(self) -> jnp.ndarray:
        """[M] int32 — rack index of each server."""
        return (jnp.arange(self.M, dtype=jnp.int32) // self.rack_size)

    @property
    def same_rack(self) -> jnp.ndarray:
        """[M, M] bool — same-rack incidence (used by JSQ-MW scheduling)."""
        r = self.rack_of
        return r[:, None] == r[None, :]


def sample_locals(key: jax.Array, cluster: Cluster, batch: int) -> jnp.ndarray:
    """Sample ``batch`` tasks' local-server triples, distinct within a task.

    Returns int32 [batch, n_replicas].  Exact sequential-skip sampling: the
    i-th replica is drawn uniformly from the M-i servers not yet chosen and
    mapped back by skipping earlier picks — O(n_replicas) ints per task
    instead of an O(M log M) Gumbel-top-k (this is the simulator's innermost
    hot path)."""
    n = cluster.n_replicas
    draws = jax.random.randint(
        key, (batch, n), 0,
        jnp.arange(cluster.M, cluster.M - n, -1, dtype=jnp.int32)[None, :])

    def place(i, picks):
        d = draws[:, i]
        # skip already-chosen indices in ascending order
        for j in range(n):  # static unroll over earlier picks (n is tiny)
            prev = jnp.sort(picks, axis=1)[:, j]
            d = jnp.where((j < i) & (d >= prev), d + 1, d)
        return picks.at[:, i].set(d)

    picks = jnp.full((batch, n), jnp.iinfo(jnp.int32).max, dtype=jnp.int32)
    for i in range(n):  # n_replicas is a small static constant (3)
        picks = place(i, picks)
    return picks.astype(jnp.int32)


def locality_class(cluster: Cluster, locals_: jnp.ndarray) -> jnp.ndarray:
    """Per-server locality class for a batch of tasks.

    locals_: int32 [..., n_replicas] — indices of each task's local servers.
    Returns int32 [..., M] with values LOCAL / RACK / REMOTE.
    """
    rack_of = cluster.rack_of
    m = jnp.arange(cluster.M, dtype=jnp.int32)
    is_local = (locals_[..., None] == m).any(axis=-2)  # [..., M]
    local_racks = rack_of[locals_]  # [..., n_replicas]
    in_local_rack = (local_racks[..., None] == rack_of[None, :]).any(axis=-2)
    cls = jnp.where(is_local, LOCAL, jnp.where(in_local_rack, RACK, REMOTE))
    return cls.astype(jnp.int32)


def capacity_arrival_rate(cluster: Cluster, rates: Rates, load: float) -> float:
    """Arrival rate (tasks/slot) at ``load`` fraction of the capacity boundary.

    With symmetric random locality every task can, at the boundary, be served
    locally, so the capacity region edge is lambda = M * alpha (paper §III-A
    specialized to the symmetric traffic used in its §V simulations).
    For heterogeneous fleets the edge generalizes to alpha * sum_m speed_m —
    scenarios compute that via scenarios.capacity_scale.
    """
    return float(load) * cluster.M * rates.alpha


# ---------------------------------------------------------------------------
# Per-server rates.  A heterogeneous fleet scales the (alpha, beta, gamma)
# class rates by a per-server speed multiplier: rate_matrix[m, c] =
# speed[m, c] * rates[c].  speed == ones reproduces the symmetric model.
# ---------------------------------------------------------------------------


def rate_matrix(rates: Rates, speed: jnp.ndarray) -> jnp.ndarray:
    """[M, 3] per-server per-class service rates.

    speed: [M] whole-server multipliers, or [M, 3] per-locality-class
    multipliers (per-tier degradation windows — repro.scenarios)."""
    speed = jnp.asarray(speed)
    if speed.ndim == 1:
        speed = speed[:, None]
    return speed * rates.as_array()[None, :]


def safe_inv_rates(rate_m: jnp.ndarray) -> jnp.ndarray:
    """Reciprocal of a rate array; zero-rate (drained / failed) entries
    carry ``+inf`` — the kernels' contract (kernels/invrates.py).

    Consumers must mask, not multiply blindly: routing scores become
    ``+inf`` AFTER the multiply (policies.weighted_score) and workload
    sums treat non-finite entries as contributing 0 (the queue_update
    kernel's semantics).  The old finite 1e9 sentinel let a drained
    server with an empty queue score 0 and absorb one task per outage
    window; ``+inf`` makes it unselectable while any live candidate
    exists."""
    return jnp.where(rate_m > 0, 1.0 / jnp.maximum(rate_m, 1e-12), jnp.inf)


def inv_rate_matrix(rates: Rates, speed: jnp.ndarray) -> jnp.ndarray:
    """[M, 3] reciprocal rates (mean service slots), +inf at speed 0."""
    return safe_inv_rates(rate_matrix(rates, speed))


# ---------------------------------------------------------------------------
# Service-duration sampling.  Durations are sampled once, at service start
# (exactly equivalent for the memoryless geometric law; required for the
# non-memoryless log-normal law), and counted down slot by slot.
# ---------------------------------------------------------------------------

GEOMETRIC = "geometric"
LOGNORMAL = "lognormal"

_MAX_DURATION = 1_000_000  # safety clip, >> any mean we use


def sample_durations(
    key: jax.Array,
    cls: jnp.ndarray,
    rates: Rates,
    dist: str = GEOMETRIC,
    sigma: float = 1.0,
) -> jnp.ndarray:
    """Sample integer service durations (slots, >= 1) for tasks of class
    ``cls`` (int32 [...], values in {LOCAL, RACK, REMOTE}).

    geometric:  P(D = k) = p (1-p)^{k-1},  mean 1/p,  p = rates[cls].
    lognormal:  ceil(LogNormal(mu_c, sigma)) with mu_c chosen so the
                continuous mean is 1/p  (heavy-tailed; paper figs 5-7).
    """
    p = rates.as_array()[cls]
    if dist == GEOMETRIC:
        u = jax.random.uniform(key, cls.shape, minval=1e-7, maxval=1.0 - 1e-7)
        d = jnp.ceil(jnp.log1p(-u) / jnp.log1p(-p))
    elif dist == LOGNORMAL:
        z = jax.random.normal(key, cls.shape)
        mu = -jnp.log(p) - 0.5 * sigma * sigma
        d = jnp.ceil(jnp.exp(mu + sigma * z))
    else:
        raise ValueError(f"unknown service distribution {dist!r}")
    return jnp.clip(d, 1, _MAX_DURATION).astype(jnp.int32)
