"""yi-34b — llama-arch GQA decoder [arXiv:2403.04652; hf].

56 q-heads do not divide the 16-way model axis; zero-masked head padding
(56 -> 64, exact semantics — see layers.head_mask) makes the layout shard
cleanly at +14% attention compute, reported in the roofline useful/computed
ratio.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_pad_to=16,
    source="[arXiv:2403.04652; hf]",
)

SMOKE = CONFIG.replace(name="yi-34b-smoke", head_pad_to=1, n_layers=2, d_model=56 * 2,
                       n_heads=7, n_kv_heads=1, d_ff=256, vocab=512)
