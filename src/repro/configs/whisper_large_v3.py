"""whisper-large-v3 — encoder-decoder transformer backbone
[arXiv:2212.04356; unverified].

Backbone only per the assignment: the conv frontend is a STUB —
input_specs() feeds precomputed frame embeddings [B, S, d_model] to the
encoder (matching the published 32-enc + 32-dec layout, d=1280, 20 heads).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_pad_to=16,
    source="[arXiv:2212.04356; unverified]",
)

SMOKE = CONFIG.replace(name="whisper-smoke", head_pad_to=1, n_layers=2, n_enc_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                       vocab=512)
