"""kimi-k2-1t-a32b — trillion-param MoE, 384 routed experts top-8
[arXiv:2501.kimi2; unverified].

Spec-literal: every layer is MoE with 384 routed experts (d_ff=2048 each),
top-8, no shared expert (the published K2 adds 1 shared expert + a dense
first layer; the assignment table omits them, so we follow the table —
noted in DESIGN.md).  fsdp=True by default: at ~1.03e12 params the optimizer
state must be ZeRO-sharded over the data axis (with int8 moments) to have
any chance of fitting — see EXPERIMENTS.md §Dry-run memory table.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab=163840,
    n_experts=384, n_shared_experts=0, experts_per_token=8, moe_d_ff=2048,
    fsdp=True,
    source="[arXiv:2501.kimi2; unverified]",
)

SMOKE = CONFIG.replace(name="kimi-k2-smoke", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
                       vocab=512, n_experts=8, experts_per_token=2,
                       moe_d_ff=64, fsdp=False)
