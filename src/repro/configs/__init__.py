"""Architecture configs: one module per assigned arch (+ shapes/registry)."""
from .base import (ARCH_IDS, SHAPES, SUBQUADRATIC_FAMILIES, ArchConfig,
                   ShapeSpec, all_cells, canonical, get, shape_applicable)

__all__ = ["ARCH_IDS", "SHAPES", "SUBQUADRATIC_FAMILIES", "ArchConfig",
           "ShapeSpec", "all_cells", "canonical", "get", "shape_applicable"]
