"""zamba2-2.7b — Mamba2 backbone + shared attention block every 6 layers
[arXiv:2411.15242; hf].

54 Mamba2 layers; one weight-shared {GQA attention + SwiGLU} block applied
after every 6th SSM layer (9 applications).  The published model also
concatenates the initial embedding into the shared block input and applies
per-invocation LoRA deltas; both are simplified away here (DESIGN.md §5).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
    source="[arXiv:2411.15242; hf]",
)

SMOKE = CONFIG.replace(name="zamba2-smoke", n_layers=4, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
                       ssm_state=16, ssm_head_dim=16, attn_every=2)
