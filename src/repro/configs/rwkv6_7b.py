"""rwkv6-7b (Finch) — attention-free linear RNN with data-dependent decay
[arXiv:2404.05892; hf].

64 WKV heads of dim 64 (d_model 4096); channel-mix d_ff 14336.  Decode is
O(1)-state, so this arch runs the long_500k cell.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab=65536, ssm_head_dim=64,
    source="[arXiv:2404.05892; hf]",
)

SMOKE = CONFIG.replace(name="rwkv6-smoke", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
                       ssm_head_dim=16)
