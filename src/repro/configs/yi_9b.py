"""yi-9b — llama-arch GQA decoder [arXiv:2403.04652; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000,
    source="[arXiv:2403.04652; hf]",
)

SMOKE = CONFIG.replace(name="yi-9b-smoke", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=1, d_ff=160, vocab=512)
