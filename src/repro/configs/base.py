"""Architecture config schema + registry + assigned input shapes.

One file per assigned architecture lives next to this module; each exposes
``CONFIG`` (the exact published dims) and ``SMOKE`` (a reduced same-family
variant for CPU smoke tests).  ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

FAMILIES = ("dense", "moe", "hybrid", "encdec", "vlm", "ssm")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # hybrid (zamba-style): shared attn+MLP block applied every k SSM layers
    attn_every: int = 0
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    # enc-dec
    n_enc_layers: int = 0
    # vlm
    n_img_tokens: int = 0
    # common
    head_pad_to: int = 1        # pad heads to this multiple (16 on the pod)
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # distribution knobs (overridable per dry-run cell)
    fsdp: bool = False          # ZeRO-3: shard params+opt over the data axis
    remat: bool = True          # rematerialize each layer in the backward pass
    train_microbatches: int = 4  # grad-accumulation splits of the global batch
    # attention flash-chunking block sizes (train/prefill path)
    q_block: int = 512
    kv_block: int = 1024
    # source citation ([source; verified-tier] from the assignment)
    source: str = ""

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    # -- zero-masked head padding (exact; Megatron-style) ---------------
    # When n_heads doesn't divide the 16-way model axis, padded heads with
    # zero wq/wo (kept zero by an output mask, so grads never touch them)
    # make the layout shardable with +pad compute, NO extra collectives,
    # and bit-exact semantics.  GQA pads the group (q-heads per kv head);
    # MHA pads kv+q together.  head_pad_to=1 (default) is a no-op.
    @property
    def padded_kv_heads(self) -> int:
        if self.head_pad_to <= 1 or self.q_groups > 1:
            return self.n_kv_heads
        return -(-self.n_kv_heads // self.head_pad_to) * self.head_pad_to

    @property
    def padded_q_groups(self) -> int:
        if self.head_pad_to <= 1 or self.q_groups == 1:
            return self.q_groups
        g = self.q_groups
        while (self.n_kv_heads * g) % self.head_pad_to:
            g += 1
        return g

    @property
    def padded_heads(self) -> int:
        return self.padded_kv_heads * self.padded_q_groups

    @property
    def padded_vocab(self) -> int:
        """vocab padded to a multiple of 2048 so a 16-way model shard stays
        128-lane aligned (padding overhead <= 4%, reported in roofline)."""
        return -(-self.vocab // 2048) * 2048

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"
    needs_subquadratic: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode",
                           needs_subquadratic=True),
}

# families whose decode path is sub-quadratic in context (O(1)-state or
# linear-cost shared-attention reads) — the only ones that run long_500k.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")

ARCH_IDS = (
    "granite_3_8b",
    "yi_34b",
    "yi_9b",
    "llama3_8b",
    "kimi_k2_1t_a32b",
    "deepseek_moe_16b",
    "zamba2_2_7b",
    "whisper_large_v3",
    "internvl2_2b",
    "rwkv6_7b",
)


def canonical(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE if smoke else mod.CONFIG


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch x shape) is an executable cell; else the skip reason
    (DESIGN.md §Arch-applicability)."""
    if shape.needs_subquadratic and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("full-attention decode is O(seq) memory per replica at "
                       "524k context; sanctioned skip for pure full-attention "
                       "archs (run for ssm/hybrid only)")
    return True, ""


def all_cells():
    """All 40 assigned (arch, shape) cells, applicable or not."""
    for arch in ARCH_IDS:
        cfg = get(arch)
        for shape in SHAPES.values():
            ok, reason = shape_applicable(cfg, shape)
            yield cfg, shape, ok, reason
