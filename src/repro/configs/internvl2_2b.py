"""internvl2-2b — InternViT + InternLM2 VLM [arXiv:2404.16821; hf].

Backbone only per the assignment: the ViT frontend is a STUB —
input_specs() provides 256 precomputed patch embeddings per sample, which
the model prepends to the token stream.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, n_img_tokens=256,
    source="[arXiv:2404.16821; hf]",
)

SMOKE = CONFIG.replace(name="internvl2-smoke", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
                       n_img_tokens=8)
