"""Telemetry: in-jit windowed metrics, distribution collectors, and
probe-quality instrumentation — the reporting spine of the repro.

Why
---
The paper's claims are distributional (BP-Pod ~ BP at low/medium load;
BP-Pod far less sensitive to d than JSQ-MW-Pod), but a run-level scalar
mean cannot show *when* a scenario destabilizes, *which* servers absorb
the imbalance, or *how good* the d sampled probes actually were.  This
package adds those observables without leaving the jit'd slot scan: all
collectors are pytree state threaded through ``jax.lax.scan`` — no
recompiles (static shapes from ``TelemetryConfig``), no host round-trips,
and **zero dynamics perturbation** (collectors never consume PRNG keys;
telemetry-off runs are bit-identical — tests/test_telemetry.py enforces
both).

The three layers
----------------
**Windowed time series** (``collectors.Telemetry.win`` / ``win_max``):
the run's T slots are split into ``n_windows`` equal windows
(``window_len = ceil(T / n_windows)``; the ragged last window is
narrower).  Per window, SUM channels (``collectors.WINDOW_SUMS``): slot
count, tasks-in-system, per-class queue mass, completions, busy servers,
arrivals + clipped arrivals, mean/max per-server workload, probe
rank/regret/decision counts; MAX channels (``WINDOW_MAXES``): peak N and
peak workload.  Export derives means (``export.window_records``) and the
windowed drift diagnostic (``export.windowed_drift``).

**Distribution collectors**: per-window log-spaced histograms of
per-server queue length and per-server workload, plus a whole-run
per-task sojourn histogram.  The bin convention lives in ``hist.py``
(shared with the serve engine): value v -> bin
``floor(bins_per_octave * log2(v + 1))``, so bin b covers
``[2^(b/bpo) - 1, 2^((b+1)/bpo) - 1)`` — constant ~9% relative width at
the default 8 bins/octave, which is what lets ``hist.percentiles`` read
p50/p95/p99 within a few percent (validated <5% against refsim's exact
per-task sojourns).  Sojourns are tracked refsim-style: each sub-queue
carries a static-shape FIFO ring of arrival slots (push at routing, pop
at service start, histogram record at completion); ring overflow drops
the *record*, never the task, and is counted in ``sojourn_dropped``.

**Probe quality** (Pod policies): per pod decision, the rank of the
chosen server's score among all M (0 = the probe set contained the global
optimum) and the score regret vs the O(M) argmin/argmax.  This is the
paper's d-sensitivity claim as a direct observable: BP-Pod's regret stays
flat as d shrinks; JSQ-MW-Pod's grows.

Sinks
-----
``export`` converts collected pytrees to a JSONL event stream (schema in
``export.__doc__``: run manifest -> window rows -> histograms ->
percentiles) consumed by ``benchmarks/scenarios.py --metrics-out=FILE``
and validated by ``scripts/validate_telemetry.py`` in CI.
``benchmarks/router_bench.py`` appends routing-throughput datapoints to
``BENCH_router.json`` for a PR-over-PR perf trajectory.

Entry points: ``core.simulate_with_telemetry`` /
``core.simulate_grid_with_telemetry`` return ``(SimResult, Telemetry)``.
"""
from .collectors import (
    WINDOW_MAXES,
    WINDOW_SUMS,
    Telemetry,
    TelemetryConfig,
    ZERO_PROBE,
    collect_step,
    probe_stats_max,
    probe_stats_min,
    record_sojourns,
    ring_pop,
    ring_push,
    zero_telemetry,
)
from .export import (
    SCHEMA_VERSION,
    WarmupPolicy,
    WarmupReport,
    aggregate,
    auto_extend_warmup,
    cell_view,
    format_clip_warning,
    probe_summary,
    read_jsonl,
    run_manifest,
    sojourn_percentiles,
    tail_stats,
    to_events,
    validate_events,
    window_records,
    windowed_drift,
    write_jsonl,
)
from .hist import (
    BINS_PER_OCTAVE,
    N_BINS,
    bin_edges,
    bin_index,
    np_hist,
    percentiles,
)

__all__ = [n for n in dir() if not n.startswith("_")]
