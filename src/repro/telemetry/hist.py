"""Log-spaced histogram vocabulary shared by the in-jit collectors, the
serve engine, and the host-side exporters.

Bin convention (the ONE convention everything in this repo uses):

  a non-negative value ``v`` falls in bin
      b(v) = clip(floor(bins_per_octave * log2(v + 1)), 0, n_bins - 1)
  so bin ``b`` covers the half-open interval
      [ 2^(b / bpo) - 1,  2^((b+1) / bpo) - 1 )

Properties that make this the right shape for queueing telemetry:
  - bin 0 is exactly {v in [0, 2^(1/bpo) - 1)} — empty queues / zero delays
    get their own bin instead of polluting a log bin anchored at 1;
  - relative bin width is constant (2^(1/bpo) - 1, ~9% at the default
    bins_per_octave = 8), so a p50/p95/p99 read off the histogram by
    linear interpolation inside the bin is accurate to a few percent
    regardless of scale — the property the <5%-vs-refsim acceptance test
    leans on;
  - the default 128 bins x 8 bins/octave cover [0, 2^16) — four orders of
    magnitude of slots/tasks — in 512 bytes of f32 counts, cheap enough to
    carry one histogram per telemetry window inside the jit'd scan.

``bin_index`` is the jit-side half (pure jnp, static shape); everything
else is host-side numpy.
"""
from __future__ import annotations

import numpy as np

N_BINS = 128
BINS_PER_OCTAVE = 8


def bin_index(v, n_bins: int = N_BINS, bins_per_octave: int = BINS_PER_OCTAVE):
    """Bin index of value(s) ``v`` (jit-safe; v may be traced, any shape)."""
    import jax.numpy as jnp

    v = jnp.asarray(v, jnp.float32)
    b = jnp.floor(bins_per_octave * jnp.log2(jnp.maximum(v, 0.0) + 1.0))
    return jnp.clip(b, 0, n_bins - 1).astype(jnp.int32)


def bin_edges(n_bins: int = N_BINS,
              bins_per_octave: int = BINS_PER_OCTAVE) -> np.ndarray:
    """[n_bins + 1] float64 bin edges: edge[b] = 2^(b / bpo) - 1."""
    b = np.arange(n_bins + 1, dtype=np.float64)
    return np.exp2(b / bins_per_octave) - 1.0


def np_hist(values, n_bins: int = N_BINS,
            bins_per_octave: int = BINS_PER_OCTAVE) -> np.ndarray:
    """Host-side histogram of ``values`` under the shared bin convention
    (the serve engine's latency path; numpy mirror of the jit collector)."""
    v = np.maximum(np.asarray(values, np.float64), 0.0)
    b = np.clip(np.floor(bins_per_octave * np.log2(v + 1.0)), 0,
                n_bins - 1).astype(np.int64)
    return np.bincount(b, minlength=n_bins).astype(np.float64)


def percentiles(hist, ps, bins_per_octave: int = BINS_PER_OCTAVE):
    """Percentile estimates from a histogram of counts.

    hist: [n_bins] counts (any float/int array).  ps: iterable of
    percentiles in [0, 100].  Linear interpolation inside the bin (uniform
    density assumption — good to ~half the relative bin width).  Returns a
    list of floats; NaNs when the histogram is empty.
    """
    h = np.asarray(hist, np.float64)
    edges = bin_edges(h.shape[0], bins_per_octave)
    c = np.cumsum(h)
    total = c[-1]
    out = []
    for p in ps:
        if total <= 0:
            out.append(float("nan"))
            continue
        target = (p / 100.0) * total
        b = int(np.searchsorted(c, target, side="left"))
        b = min(b, h.shape[0] - 1)
        prev = c[b - 1] if b > 0 else 0.0
        frac = (target - prev) / max(h[b], 1e-12)
        out.append(float(edges[b] + frac * (edges[b + 1] - edges[b])))
    return out
