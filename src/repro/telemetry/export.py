"""Host-side telemetry sinks: collected pytrees -> JSONL events + manifest.

JSONL event stream (one JSON object per line), schema version 1:

  {"event": "run", "schema": 1, ...}        run manifest: scenario, algo,
      d, load, seeds, T, warmup, window_len, n_windows, wall_s,
      trace_count, plus anything the caller adds.  Always first.
  {"event": "window", "w": int, "t0": int, "t1": int, "slots": float,
      "mean_N": float, "max_N": float, "throughput": float,
      "utilization": float, "arrivals": float, "clip_fraction": float,
      "q_local"/"q_rack"/"q_remote": float, "w_mean": float,
      "w_max": float, "probe_rank": float|null, "probe_regret": float|null,
      "probe_decisions": float}             one per telemetry window.
  {"event": "histogram", "name": "sojourn"|"queue_len"|"workload",
      "window": int|null, "bins_per_octave": int, "counts": [...]}
      per-window for queue_len/workload (and an aggregate with
      window=null), whole-run for sojourn.
  {"event": "percentiles", "name": "sojourn", "p50": float, "p95": float,
      "p99": float, "n": float, "dropped": float}

``validate_events`` checks this shape (the CI smoke leg runs it over the
benchmark's --metrics-out output via scripts/validate_telemetry.py).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import NamedTuple, Optional

import numpy as np

from .collectors import WINDOW_MAXES, WINDOW_SUMS, Telemetry, TelemetryConfig
from .hist import percentiles

SCHEMA_VERSION = 1

_S = {n: i for i, n in enumerate(WINDOW_SUMS)}
_X = {n: i for i, n in enumerate(WINDOW_MAXES)}


def cell_view(tele: Telemetry, idx) -> Telemetry:
    """Index one grid cell (or a cell slab) out of batched telemetry.

    ``simulate_grid`` / ``simulate_sweep`` telemetry carries leading batch
    axes on every leaf ([seeds, loads] resp. [scenarios, seeds, loads]);
    ``idx`` is any numpy index into those axes — e.g. ``(s, slice(None),
    l)`` for one sweep cell's seed replications.  The mega-sweep contract
    is that collectors reduce PER CELL: always slice the cell first with
    this and aggregate the remainder, never ``aggregate`` across cells of
    different scenarios/loads (their windows would sum into one
    meaningless series).  Rings are per-run state and are dropped.
    """
    f = lambda x: None if x is None else np.asarray(x)[idx]  # noqa: E731
    return Telemetry(
        win=f(tele.win), win_max=f(tele.win_max),
        qlen_hist=f(tele.qlen_hist), work_hist=f(tele.work_hist),
        sojourn_hist=f(tele.sojourn_hist),
        sojourn_dropped=f(tele.sojourn_dropped),
    )


def aggregate(tele: Telemetry) -> Telemetry:
    """Reduce vmapped (``simulate_grid``) telemetry over its leading batch
    axes: counts/sums add, maxima max, rings are dropped (per-run state).
    For ``simulate_sweep`` telemetry, slice a single (scenario, load) cell
    with ``cell_view`` FIRST — aggregating across heterogeneous cells mixes
    their window series into something meaningless."""
    win = np.asarray(tele.win, np.float64)
    extra = win.ndim - 2
    if extra == 0:
        return tele._replace(ring=None, head=None, tail=None, cur_arr=None)
    ax = tuple(range(extra))
    return Telemetry(
        win=win.sum(axis=ax),
        win_max=np.asarray(tele.win_max, np.float64).max(axis=ax),
        qlen_hist=np.asarray(tele.qlen_hist, np.float64).sum(axis=ax),
        work_hist=np.asarray(tele.work_hist, np.float64).sum(axis=ax),
        sojourn_hist=np.asarray(tele.sojourn_hist, np.float64).sum(axis=ax),
        sojourn_dropped=np.asarray(tele.sojourn_dropped,
                                   np.float64).sum(),
    )


def window_records(tele: Telemetry, tcfg: TelemetryConfig, T: int) -> list:
    """Derived per-window rows (means from sums; empty windows skipped)."""
    tele = aggregate(tele)
    win = np.asarray(tele.win, np.float64)
    wmax = np.asarray(tele.win_max, np.float64)
    wl = tcfg.window_len(T)
    rows = []
    for w in range(win.shape[0]):
        slots = win[w, _S["slots"]]
        if slots <= 0:
            continue
        s = lambda n: float(win[w, _S[n]])  # noqa: E731
        arr = s("arrivals")
        probe_n = s("probe_decisions")
        rows.append({
            "event": "window", "w": w, "t0": w * wl,
            "t1": min((w + 1) * wl, T), "slots": slots,
            "mean_N": s("sum_N") / slots,
            "max_N": float(wmax[w, _X["max_N"]]),
            "throughput": s("completions") / slots,
            "utilization": s("busy") / slots,   # busy-server slots per slot
            "arrivals": arr / slots,
            "clip_fraction": s("clipped") / max(arr + s("clipped"), 1.0),
            "q_local": s("q_local") / slots,
            "q_rack": s("q_rack") / slots,
            "q_remote": s("q_remote") / slots,
            "w_mean": s("w_mean") / slots,
            "w_max": s("w_max") / slots,
            "probe_rank": s("probe_rank") / probe_n if probe_n else None,
            "probe_regret": s("probe_regret") / probe_n if probe_n else None,
            "probe_decisions": probe_n,
        })
    return rows


def probe_summary(tele: Telemetry) -> dict:
    """Run-level mean probe rank / regret over all pod decisions."""
    win = np.asarray(aggregate(tele).win, np.float64).sum(axis=0)
    n = win[_S["probe_decisions"]]
    return {
        "decisions": float(n),
        "mean_rank": float(win[_S["probe_rank"]] / n) if n else None,
        "mean_regret": float(win[_S["probe_regret"]] / n) if n else None,
    }


def sojourn_percentiles(tele: Telemetry, tcfg: TelemetryConfig,
                        ps=(50, 95, 99)) -> dict:
    """Per-task sojourn p50/p95/p99 (slots) from the run's log-spaced
    histogram, plus sample count and dropped-record count."""
    tele = aggregate(tele)
    hist = np.asarray(tele.sojourn_hist, np.float64)
    vals = percentiles(hist, ps, tcfg.bins_per_octave)
    out = {f"p{p}": v for p, v in zip(ps, vals)}
    out["n"] = float(hist.sum())
    out["dropped"] = float(np.asarray(tele.sojourn_dropped))
    if out["n"] == 0:
        # NaN percentiles are deliberate — an empty histogram has no
        # quantiles; consumers surface this note instead of printing NaN
        # as if it were a measurement (benchmarks/scenarios.py)
        out["note"] = ("empty sojourn histogram (0 completions recorded): "
                       "percentiles are NaN")
    return out


def windowed_drift(tele: Telemetry, tcfg: TelemetryConfig, T: int,
                   warmup: int) -> float:
    """Drift from the telemetry ring: mean N over the last quarter of
    post-warmup windows divided by the first quarter.  ~1 means the chain
    mixed; >> 1 means still growing (slow mixing or supercritical) — the
    windowed upgrade of SimResult.drift's single half2/half1 ratio, and
    the signal ``auto_extend_warmup`` consumes.

    Returns NaN when fewer than 2 measured windows remain after ``warmup``
    — drift is then UNMEASURABLE, and consumers must treat that as "not
    converged / extend", never as "converged" (the auto-extend loop and
    the benchmark tables both guard this)."""
    tele = aggregate(tele)
    win = np.asarray(tele.win, np.float64)
    wl = tcfg.window_len(T)
    w0 = -(-warmup // wl)                        # first fully-measured window
    slots = win[w0:, _S["slots"]]
    meas = np.where(slots > 0)[0]
    if len(meas) < 2:
        return float("nan")
    mean_N = win[w0:, _S["sum_N"]][meas] / slots[meas]
    k = max(1, len(meas) // 4)
    head, tail = mean_N[:k].mean(), mean_N[-k:].mean()
    return float(tail / max(head, 1e-9))


# ---------------------------------------------------------------------------
# Drift-aware auto-extend warmup (ROADMAP: slow-mixing scenarios must
# converge before measurement).  Window sums are EXACT per-slot sums, so
# moving the measurement boundary to a later window boundary and re-deriving
# the tail statistics is equivalent to having run with that longer warmup —
# no re-run, no retrace, the one-compile sweep invariant holds trivially.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WarmupPolicy:
    """Knobs of the auto-extend warmup loop (``auto_extend_warmup``).

    threshold        converged when windowed drift < this (1.05 = the last
                     quarter of measured windows is within 5% of the first)
    chunk_windows    extend the warmup boundary by this many telemetry
                     windows per step
    max_warmup_frac  hard cap: never push warmup past this fraction of T
                     (measurement needs a tail; past the cap the honest
                     answer is "lengthen the run", not "trim harder")
    min_tail_windows stop extending when fewer measured windows than this
                     would remain (a 2-window drift estimate is noise)
    """

    threshold: float = 1.05
    chunk_windows: int = 2
    max_warmup_frac: float = 0.75
    min_tail_windows: int = 4


class WarmupReport(NamedTuple):
    """Outcome of one auto-extend warmup pass (``auto_extend_warmup``).

    ``warmup`` is the REALIZED measurement boundary (slots; recorded in
    benchmark rows and JSONL manifests), ``drift`` the windowed drift of
    the surviving tail, and the trailing fields the tail's re-derived
    metrics (means over the post-``warmup`` windows; ``mean_completion``
    is Little's-law slots).  ``converged`` is False whenever drift is NaN
    (unmeasurable — never treated as converged) or still >= threshold at
    the cap; ``note`` then says why, loudly."""

    warmup0: int
    warmup: int
    extensions: int
    converged: bool
    drift0: float
    drift: float
    threshold: float
    mean_N: float
    lam_hat: float
    mean_completion: float
    throughput: float
    note: str = ""

    def fields(self) -> dict:
        """Manifest/benchmark-row fields (JSON-safe floats)."""
        return {
            "warmup0": self.warmup0,
            "warmup_realized": self.warmup,
            "warmup_extensions": self.extensions,
            "warmup_converged": self.converged,
            "drift_windowed0": float(self.drift0),
            "drift_windowed": float(self.drift),
            "drift_threshold": float(self.threshold),
            **({"warmup_note": self.note} if self.note else {}),
        }


def tail_stats(tele: Telemetry, tcfg: TelemetryConfig, T: int,
               warmup: int) -> dict:
    """Re-derive run metrics from the telemetry windows at/after the
    ``warmup`` boundary (exact: window sums are per-slot sums, so this
    equals a run measured with that warmup up to window granularity).
    Returns mean_N / lam_hat / mean_completion (Little's law, slots) /
    throughput — NaN-filled when no measured window survives."""
    tele = aggregate(tele)
    win = np.asarray(tele.win, np.float64)
    wl = tcfg.window_len(T)
    w0 = -(-int(warmup) // wl)
    tail = win[w0:]
    slots = float(tail[:, _S["slots"]].sum())
    if slots <= 0:
        nan = float("nan")
        return {"mean_N": nan, "lam_hat": nan, "mean_completion": nan,
                "throughput": nan}
    mean_N = float(tail[:, _S["sum_N"]].sum()) / slots
    lam_hat = float(tail[:, _S["arrivals"]].sum()) / slots
    return {
        "mean_N": mean_N,
        "lam_hat": lam_hat,
        "mean_completion": mean_N / max(lam_hat, 1e-9),
        "throughput": float(tail[:, _S["completions"]].sum()) / slots,
    }


def auto_extend_warmup(tele: Telemetry, tcfg: TelemetryConfig, T: int,
                       warmup: int,
                       policy: WarmupPolicy = WarmupPolicy()
                       ) -> WarmupReport:
    """The drift-aware warmup control loop (ROADMAP auto-extend).

    Starting from the run's configured ``warmup``, extend the measurement
    boundary in chunks of ``policy.chunk_windows`` telemetry windows while
    the windowed drift of the remaining tail is >= ``policy.threshold``,
    stopping at the ``max_warmup_frac`` cap or when the surviving tail
    gets too short to judge (``min_tail_windows``).  A NaN drift
    (unmeasurable: < 2 measured windows) is NEVER treated as converged —
    the report comes back converged=False with a loud note.

    Works on collected window sums only — the simulation is not re-run and
    nothing retraces, so a fast-mixing run (drift already below threshold)
    costs zero extensions and a sweep's trace_count stays at 1.  Use
    ``core.simulate_auto_warmup`` for the one-call version.
    """
    tele = aggregate(tele)
    wl = tcfg.window_len(T)
    cap = int(policy.max_warmup_frac * T)
    win = np.asarray(tele.win, np.float64)
    measured_after = lambda w: int(  # noqa: E731
        (win[-(-int(w) // wl):, _S["slots"]] > 0).sum())
    w = int(warmup)
    drift0 = windowed_drift(tele, tcfg, T, w)
    drift = drift0
    extensions = 0
    note = ""
    while not math.isnan(drift) and drift >= policy.threshold:
        nxt = w + policy.chunk_windows * wl
        if nxt > cap:
            note = (f"NOT converged: drift {drift:.3f} >= "
                    f"{policy.threshold} at the warmup cap ({cap} slots = "
                    f"{policy.max_warmup_frac:.0%} of T) — lengthen the "
                    "run (larger T), the tail cannot be trimmed further")
            break
        if measured_after(nxt) < policy.min_tail_windows:
            note = (f"NOT converged: drift {drift:.3f} >= "
                    f"{policy.threshold} but only "
                    f"{measured_after(nxt)} measured windows would remain "
                    f"(< min_tail_windows={policy.min_tail_windows}) — "
                    "lengthen the run (larger T)")
            break
        w = nxt
        extensions += 1
        drift = windowed_drift(tele, tcfg, T, w)
    if math.isnan(drift):
        converged = False
        if not note:
            note = ("drift UNMEASURABLE (fewer than 2 measured telemetry "
                    "windows after warmup) — treated as NOT converged; "
                    "lengthen the run or use more telemetry windows")
    else:
        converged = bool(drift < policy.threshold)
    return WarmupReport(
        warmup0=int(warmup), warmup=w, extensions=extensions,
        converged=converged, drift0=float(drift0), drift=float(drift),
        threshold=float(policy.threshold), note=note,
        **tail_stats(tele, tcfg, T, w))


# ---------------------------------------------------------------------------
# JSONL events
# ---------------------------------------------------------------------------


def run_manifest(**fields) -> dict:
    """The run-manifest event; callers pass scenario/algo/d/load/seeds/
    T/warmup/wall_s/trace_count and any extra context."""
    return {"event": "run", "schema": SCHEMA_VERSION, **fields}


def to_events(tele: Telemetry, tcfg: TelemetryConfig, T: int, warmup: int,
              manifest: Optional[dict] = None,
              per_window_hists: bool = False) -> list:
    """Flatten one run's collected telemetry into the JSONL event list."""
    tele = aggregate(tele)
    events = []
    if manifest is not None:
        m = dict(manifest)
        m.setdefault("event", "run")
        m.setdefault("schema", SCHEMA_VERSION)
        m["n_windows"] = tcfg.n_windows
        m["window_len"] = tcfg.window_len(T)
        m["drift_windowed"] = windowed_drift(tele, tcfg, T, warmup)
        events.append(m)
    events.extend(window_records(tele, tcfg, T))
    bpo = tcfg.bins_per_octave
    for name, h in (("queue_len", tele.qlen_hist),
                    ("workload", tele.work_hist)):
        h = np.asarray(h, np.float64)
        events.append({"event": "histogram", "name": name, "window": None,
                       "bins_per_octave": bpo,
                       "counts": h.sum(axis=0).tolist()})
        if per_window_hists:
            for w in range(h.shape[0]):
                if h[w].sum() > 0:
                    events.append({"event": "histogram", "name": name,
                                   "window": w, "bins_per_octave": bpo,
                                   "counts": h[w].tolist()})
    events.append({"event": "histogram", "name": "sojourn", "window": None,
                   "bins_per_octave": bpo,
                   "counts": np.asarray(tele.sojourn_hist,
                                        np.float64).tolist()})
    events.append({"event": "percentiles", "name": "sojourn",
                   **sojourn_percentiles(tele, tcfg)})
    return events


def write_jsonl(path: str, events: list, append: bool = True) -> None:
    """Write events (one JSON object per line) to ``path``, creating parent
    directories; ``append=False`` truncates an existing file."""
    parent = os.path.dirname(os.path.abspath(path))
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "a" if append else "w") as f:
        for e in events:
            f.write(json.dumps(e) + "\n")


def read_jsonl(path: str) -> list:
    """Load a JSONL event stream back into a list of dicts (blank lines
    skipped) — the inverse of ``write_jsonl``."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


_REQUIRED = {
    "run": ("schema",),
    "window": ("w", "t0", "t1", "slots", "mean_N", "max_N", "throughput",
               "utilization", "arrivals", "clip_fraction"),
    "histogram": ("name", "window", "bins_per_octave", "counts"),
    "percentiles": ("name", "n"),
}


def validate_events(events: list) -> list:
    """Schema check; returns a list of error strings (empty == valid)."""
    errors = []
    if not events:
        return ["empty event stream"]
    if events[0].get("event") != "run":
        errors.append("first event must be the run manifest")
    for i, e in enumerate(events):
        kind = e.get("event")
        if kind not in _REQUIRED:
            errors.append(f"line {i + 1}: unknown event {kind!r}")
            continue
        missing = [k for k in _REQUIRED[kind] if k not in e]
        if missing:
            errors.append(f"line {i + 1} ({kind}): missing {missing}")
        if kind == "run" and e.get("schema") != SCHEMA_VERSION:
            errors.append(f"line {i + 1}: schema {e.get('schema')} != "
                          f"{SCHEMA_VERSION}")
        if kind == "histogram" and not isinstance(e.get("counts"), list):
            errors.append(f"line {i + 1}: histogram counts must be a list")
    return errors


# ---------------------------------------------------------------------------
# Clip-fraction surfacing (satellite): silent arrival clipping biases
# results invisibly — callers of simulate_grid print this loudly.
# ---------------------------------------------------------------------------


def format_clip_warning(cells: list) -> Optional[str]:
    """cells: [(label, clip_fraction), ...]; returns a loud multi-line
    warning for the clipped ones, or None when nothing clipped."""
    hot = [(lbl, f) for lbl, f in cells if f > 0]
    if not hot:
        return None
    lines = ["!" * 72,
             "! WARNING: arrival clipping detected — Poisson draws above "
             "a_max were",
             "! truncated; measured loads are BIASED LOW in these cells "
             "(raise a_max):"]
    for lbl, f in sorted(hot, key=lambda x: -x[1]):
        lines.append(f"!   {lbl}: clip_fraction = {f:.3e}")
    lines.append("!" * 72)
    return "\n".join(lines)
