"""Host-side telemetry sinks: collected pytrees -> JSONL events + manifest.

JSONL event stream (one JSON object per line), schema version 1:

  {"event": "run", "schema": 1, ...}        run manifest: scenario, algo,
      d, load, seeds, T, warmup, window_len, n_windows, wall_s,
      trace_count, plus anything the caller adds.  Always first.
  {"event": "window", "w": int, "t0": int, "t1": int, "slots": float,
      "mean_N": float, "max_N": float, "throughput": float,
      "utilization": float, "arrivals": float, "clip_fraction": float,
      "q_local"/"q_rack"/"q_remote": float, "w_mean": float,
      "w_max": float, "probe_rank": float|null, "probe_regret": float|null,
      "probe_decisions": float}             one per telemetry window.
  {"event": "histogram", "name": "sojourn"|"queue_len"|"workload",
      "window": int|null, "bins_per_octave": int, "counts": [...]}
      per-window for queue_len/workload (and an aggregate with
      window=null), whole-run for sojourn.
  {"event": "percentiles", "name": "sojourn", "p50": float, "p95": float,
      "p99": float, "n": float, "dropped": float}

``validate_events`` checks this shape (the CI smoke leg runs it over the
benchmark's --metrics-out output via scripts/validate_telemetry.py).
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

from .collectors import WINDOW_MAXES, WINDOW_SUMS, Telemetry, TelemetryConfig
from .hist import percentiles

SCHEMA_VERSION = 1

_S = {n: i for i, n in enumerate(WINDOW_SUMS)}
_X = {n: i for i, n in enumerate(WINDOW_MAXES)}


def cell_view(tele: Telemetry, idx) -> Telemetry:
    """Index one grid cell (or a cell slab) out of batched telemetry.

    ``simulate_grid`` / ``simulate_sweep`` telemetry carries leading batch
    axes on every leaf ([seeds, loads] resp. [scenarios, seeds, loads]);
    ``idx`` is any numpy index into those axes — e.g. ``(s, slice(None),
    l)`` for one sweep cell's seed replications.  The mega-sweep contract
    is that collectors reduce PER CELL: always slice the cell first with
    this and aggregate the remainder, never ``aggregate`` across cells of
    different scenarios/loads (their windows would sum into one
    meaningless series).  Rings are per-run state and are dropped.
    """
    f = lambda x: None if x is None else np.asarray(x)[idx]  # noqa: E731
    return Telemetry(
        win=f(tele.win), win_max=f(tele.win_max),
        qlen_hist=f(tele.qlen_hist), work_hist=f(tele.work_hist),
        sojourn_hist=f(tele.sojourn_hist),
        sojourn_dropped=f(tele.sojourn_dropped),
    )


def aggregate(tele: Telemetry) -> Telemetry:
    """Reduce vmapped (``simulate_grid``) telemetry over its leading batch
    axes: counts/sums add, maxima max, rings are dropped (per-run state).
    For ``simulate_sweep`` telemetry, slice a single (scenario, load) cell
    with ``cell_view`` FIRST — aggregating across heterogeneous cells mixes
    their window series into something meaningless."""
    win = np.asarray(tele.win, np.float64)
    extra = win.ndim - 2
    if extra == 0:
        return tele._replace(ring=None, head=None, tail=None, cur_arr=None)
    ax = tuple(range(extra))
    return Telemetry(
        win=win.sum(axis=ax),
        win_max=np.asarray(tele.win_max, np.float64).max(axis=ax),
        qlen_hist=np.asarray(tele.qlen_hist, np.float64).sum(axis=ax),
        work_hist=np.asarray(tele.work_hist, np.float64).sum(axis=ax),
        sojourn_hist=np.asarray(tele.sojourn_hist, np.float64).sum(axis=ax),
        sojourn_dropped=np.asarray(tele.sojourn_dropped,
                                   np.float64).sum(),
    )


def window_records(tele: Telemetry, tcfg: TelemetryConfig, T: int) -> list:
    """Derived per-window rows (means from sums; empty windows skipped)."""
    tele = aggregate(tele)
    win = np.asarray(tele.win, np.float64)
    wmax = np.asarray(tele.win_max, np.float64)
    wl = tcfg.window_len(T)
    rows = []
    for w in range(win.shape[0]):
        slots = win[w, _S["slots"]]
        if slots <= 0:
            continue
        s = lambda n: float(win[w, _S[n]])  # noqa: E731
        arr = s("arrivals")
        probe_n = s("probe_decisions")
        rows.append({
            "event": "window", "w": w, "t0": w * wl,
            "t1": min((w + 1) * wl, T), "slots": slots,
            "mean_N": s("sum_N") / slots,
            "max_N": float(wmax[w, _X["max_N"]]),
            "throughput": s("completions") / slots,
            "utilization": s("busy") / slots,   # busy-server slots per slot
            "arrivals": arr / slots,
            "clip_fraction": s("clipped") / max(arr + s("clipped"), 1.0),
            "q_local": s("q_local") / slots,
            "q_rack": s("q_rack") / slots,
            "q_remote": s("q_remote") / slots,
            "w_mean": s("w_mean") / slots,
            "w_max": s("w_max") / slots,
            "probe_rank": s("probe_rank") / probe_n if probe_n else None,
            "probe_regret": s("probe_regret") / probe_n if probe_n else None,
            "probe_decisions": probe_n,
        })
    return rows


def probe_summary(tele: Telemetry) -> dict:
    """Run-level mean probe rank / regret over all pod decisions."""
    win = np.asarray(aggregate(tele).win, np.float64).sum(axis=0)
    n = win[_S["probe_decisions"]]
    return {
        "decisions": float(n),
        "mean_rank": float(win[_S["probe_rank"]] / n) if n else None,
        "mean_regret": float(win[_S["probe_regret"]] / n) if n else None,
    }


def sojourn_percentiles(tele: Telemetry, tcfg: TelemetryConfig,
                        ps=(50, 95, 99)) -> dict:
    """Per-task sojourn p50/p95/p99 (slots) from the run's log-spaced
    histogram, plus sample count and dropped-record count."""
    tele = aggregate(tele)
    hist = np.asarray(tele.sojourn_hist, np.float64)
    vals = percentiles(hist, ps, tcfg.bins_per_octave)
    out = {f"p{p}": v for p, v in zip(ps, vals)}
    out["n"] = float(hist.sum())
    out["dropped"] = float(np.asarray(tele.sojourn_dropped))
    if out["n"] == 0:
        # NaN percentiles are deliberate — an empty histogram has no
        # quantiles; consumers surface this note instead of printing NaN
        # as if it were a measurement (benchmarks/scenarios.py)
        out["note"] = ("empty sojourn histogram (0 completions recorded): "
                       "percentiles are NaN")
    return out


def windowed_drift(tele: Telemetry, tcfg: TelemetryConfig, T: int,
                   warmup: int) -> float:
    """Drift from the telemetry ring: mean N over the last quarter of
    post-warmup windows divided by the first quarter.  ~1 means the chain
    mixed; >> 1 means still growing (slow mixing or supercritical) — the
    windowed upgrade of SimResult.drift's single half2/half1 ratio, and
    the signal ROADMAP's auto-extend warmup will consume."""
    tele = aggregate(tele)
    win = np.asarray(tele.win, np.float64)
    wl = tcfg.window_len(T)
    w0 = -(-warmup // wl)                        # first fully-measured window
    slots = win[w0:, _S["slots"]]
    meas = np.where(slots > 0)[0]
    if len(meas) < 2:
        return float("nan")
    mean_N = win[w0:, _S["sum_N"]][meas] / slots[meas]
    k = max(1, len(meas) // 4)
    head, tail = mean_N[:k].mean(), mean_N[-k:].mean()
    return float(tail / max(head, 1e-9))


# ---------------------------------------------------------------------------
# JSONL events
# ---------------------------------------------------------------------------


def run_manifest(**fields) -> dict:
    """The run-manifest event; callers pass scenario/algo/d/load/seeds/
    T/warmup/wall_s/trace_count and any extra context."""
    return {"event": "run", "schema": SCHEMA_VERSION, **fields}


def to_events(tele: Telemetry, tcfg: TelemetryConfig, T: int, warmup: int,
              manifest: Optional[dict] = None,
              per_window_hists: bool = False) -> list:
    """Flatten one run's collected telemetry into the JSONL event list."""
    tele = aggregate(tele)
    events = []
    if manifest is not None:
        m = dict(manifest)
        m.setdefault("event", "run")
        m.setdefault("schema", SCHEMA_VERSION)
        m["n_windows"] = tcfg.n_windows
        m["window_len"] = tcfg.window_len(T)
        m["drift_windowed"] = windowed_drift(tele, tcfg, T, warmup)
        events.append(m)
    events.extend(window_records(tele, tcfg, T))
    bpo = tcfg.bins_per_octave
    for name, h in (("queue_len", tele.qlen_hist),
                    ("workload", tele.work_hist)):
        h = np.asarray(h, np.float64)
        events.append({"event": "histogram", "name": name, "window": None,
                       "bins_per_octave": bpo,
                       "counts": h.sum(axis=0).tolist()})
        if per_window_hists:
            for w in range(h.shape[0]):
                if h[w].sum() > 0:
                    events.append({"event": "histogram", "name": name,
                                   "window": w, "bins_per_octave": bpo,
                                   "counts": h[w].tolist()})
    events.append({"event": "histogram", "name": "sojourn", "window": None,
                   "bins_per_octave": bpo,
                   "counts": np.asarray(tele.sojourn_hist,
                                        np.float64).tolist()})
    events.append({"event": "percentiles", "name": "sojourn",
                   **sojourn_percentiles(tele, tcfg)})
    return events


def write_jsonl(path: str, events: list, append: bool = True) -> None:
    """Write events (one JSON object per line) to ``path``, creating parent
    directories; ``append=False`` truncates an existing file."""
    parent = os.path.dirname(os.path.abspath(path))
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "a" if append else "w") as f:
        for e in events:
            f.write(json.dumps(e) + "\n")


def read_jsonl(path: str) -> list:
    """Load a JSONL event stream back into a list of dicts (blank lines
    skipped) — the inverse of ``write_jsonl``."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


_REQUIRED = {
    "run": ("schema",),
    "window": ("w", "t0", "t1", "slots", "mean_N", "max_N", "throughput",
               "utilization", "arrivals", "clip_fraction"),
    "histogram": ("name", "window", "bins_per_octave", "counts"),
    "percentiles": ("name", "n"),
}


def validate_events(events: list) -> list:
    """Schema check; returns a list of error strings (empty == valid)."""
    errors = []
    if not events:
        return ["empty event stream"]
    if events[0].get("event") != "run":
        errors.append("first event must be the run manifest")
    for i, e in enumerate(events):
        kind = e.get("event")
        if kind not in _REQUIRED:
            errors.append(f"line {i + 1}: unknown event {kind!r}")
            continue
        missing = [k for k in _REQUIRED[kind] if k not in e]
        if missing:
            errors.append(f"line {i + 1} ({kind}): missing {missing}")
        if kind == "run" and e.get("schema") != SCHEMA_VERSION:
            errors.append(f"line {i + 1}: schema {e.get('schema')} != "
                          f"{SCHEMA_VERSION}")
        if kind == "histogram" and not isinstance(e.get("counts"), list):
            errors.append(f"line {i + 1}: histogram counts must be a list")
    return errors


# ---------------------------------------------------------------------------
# Clip-fraction surfacing (satellite): silent arrival clipping biases
# results invisibly — callers of simulate_grid print this loudly.
# ---------------------------------------------------------------------------


def format_clip_warning(cells: list) -> Optional[str]:
    """cells: [(label, clip_fraction), ...]; returns a loud multi-line
    warning for the clipped ones, or None when nothing clipped."""
    hot = [(lbl, f) for lbl, f in cells if f > 0]
    if not hot:
        return None
    lines = ["!" * 72,
             "! WARNING: arrival clipping detected — Poisson draws above "
             "a_max were",
             "! truncated; measured loads are BIASED LOW in these cells "
             "(raise a_max):"]
    for lbl, f in sorted(hot, key=lambda x: -x[1]):
        lines.append(f"!   {lbl}: clip_fraction = {f:.3e}")
    lines.append("!" * 72)
    return "\n".join(lines)
