"""In-jit telemetry collectors: pytree state updated inside the slot scan.

Everything here obeys two hard contracts (tests/test_telemetry.py):

  1. **No dynamics perturbation.**  Collectors never consume PRNG keys and
     never feed back into routing/scheduling — a simulation with telemetry
     enabled is bit-identical (same RawSums) to one without.
  2. **No recompiles.**  All collector state has static shapes derived from
     ``TelemetryConfig`` (a hashable static jit argument) and the cluster
     size, so a whole scenario sweep with one config shares one compiled
     signature, exactly like the telemetry-off sweep.

State layout (the ``Telemetry`` pytree):

  win        [W, n sum channels]  per-window accumulators (WINDOW_SUMS
             names the channels; slot values are scatter-added into window
             w = t // window_len, window_len = ceil(T / W))
  win_max    [W, n max channels]  per-window running maxima (WINDOW_MAXES)
  qlen_hist  [W, B]  per-window histogram of per-server queue lengths
  work_hist  [W, B]  per-window histogram of per-server workloads
             (B log-spaced bins — see hist.py for the shared convention)
  sojourn_hist [B]   per-task sojourn (arrival -> service completion)
             histogram, post-warmup tasks only — the distributional delay
             estimate validated against refsim's exact per-task sojourns
  sojourn_dropped    f32 count of tasks whose arrival slot could not be
             recorded (per-queue FIFO ring overflow; 0 at calibration
             loads — nonzero values mean percentile estimates are biased
             and are surfaced in the export manifest)
  ring/head/tail/cur_arr   the FIFO arrival-slot rings behind the sojourn
             histogram (BP: one ring per (server, class) sub-queue; SQ:
             one per server; FCFS: disabled).  ``cur_arr[m]`` is the
             arrival slot of server m's in-service task (-1 = unknown).

Probe-quality channels (Pod policies): per pod decision, the *rank* of the
chosen server's score among all M candidates the O(M) policy would have
examined (0 = the pod probe found the global optimum) and the *regret*
(chosen score minus the global optimum — the workload the decision left on
the table).  This is the direct observable behind the paper's d-sensitivity
claim: BP-Pod's regret stays flat as d shrinks, JSQ-MW-Pod's does not.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp

from .hist import BINS_PER_OCTAVE, N_BINS, bin_index

# ---------------------------------------------------------------------------
# Static config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static collector parameters (hashable: safe as a jit static arg).

    A sweep that shares one TelemetryConfig shares one compiled signature.
    """

    n_windows: int = 64          # W: windowed-time-series resolution
    n_bins: int = N_BINS         # B: histogram bins (hist.py convention)
    bins_per_octave: int = BINS_PER_OCTAVE
    sojourns: bool = True        # per-task sojourn histogram (BP/SQ)
    probes: bool = True          # pod probe rank/regret channels
    ring_cap: int = 128          # FIFO arrival-slot records per queue

    def window_len(self, T: int) -> int:
        """Slots per window: ceil(T / n_windows); last window ragged."""
        return max(1, -(-T // self.n_windows))


# Per-window SUM channels (accumulated with scatter-add; a slot's values
# land in window t // window_len).  "slots" counts slots so means are
# sums / slots at export time.
WINDOW_SUMS = (
    "slots", "sum_N", "q_local", "q_rack", "q_remote", "completions",
    "busy", "arrivals", "clipped", "w_mean", "w_max",
    "probe_rank", "probe_regret", "probe_decisions",
)
# Per-window MAX channels (accumulated with scatter-max).
WINDOW_MAXES = ("max_N", "max_w")

_S = {n: i for i, n in enumerate(WINDOW_SUMS)}
_X = {n: i for i, n in enumerate(WINDOW_MAXES)}


class Telemetry(NamedTuple):
    """Collector state carried through the slot scan (see module doc)."""

    win: jnp.ndarray
    win_max: jnp.ndarray
    qlen_hist: jnp.ndarray
    work_hist: jnp.ndarray
    sojourn_hist: jnp.ndarray
    sojourn_dropped: jnp.ndarray
    ring: Optional[jnp.ndarray] = None      # [NQ + 1, cap] int32 (dummy row)
    head: Optional[jnp.ndarray] = None      # [NQ + 1] int32
    tail: Optional[jnp.ndarray] = None      # [NQ + 1] int32
    cur_arr: Optional[jnp.ndarray] = None   # [M] int32, -1 = unknown


def zero_telemetry(tcfg: TelemetryConfig, M: int, family: str) -> Telemetry:
    """Fresh collector state for one run.

    family: "bp" (per-(server, class) sub-queues), "sq" (one queue per
    server) or "fcfs" (central queue — sojourn rings disabled: the grabbed
    task's identity is sampled at dequeue, so no per-task arrival slot
    exists to record).
    """
    W, B = tcfg.n_windows, tcfg.n_bins
    z32 = jnp.zeros
    ring = head = tail = cur_arr = None
    if tcfg.sojourns and family in ("bp", "sq"):
        nq = 3 * M if family == "bp" else M
        ring = jnp.full((nq + 1, tcfg.ring_cap), -1, jnp.int32)
        head = z32(nq + 1, jnp.int32)
        tail = z32(nq + 1, jnp.int32)
        cur_arr = jnp.full((M,), -1, jnp.int32)
    return Telemetry(
        win=z32((W, len(WINDOW_SUMS)), jnp.float32),
        win_max=z32((W, len(WINDOW_MAXES)), jnp.float32),
        qlen_hist=z32((W, B), jnp.float32),
        work_hist=z32((W, B), jnp.float32),
        sojourn_hist=z32(B, jnp.float32),
        sojourn_dropped=jnp.float32(0.0),
        ring=ring, head=head, tail=tail, cur_arr=cur_arr,
    )


# ---------------------------------------------------------------------------
# Windowed time series + per-window distributions
# ---------------------------------------------------------------------------


def collect_step(tele: Telemetry, tcfg: TelemetryConfig, *, t, T: int,
                 N, q_mass, qlen, workload, arrivals, clipped, completions,
                 busy_n, probe) -> Telemetry:
    """Fold one slot's observables into the windowed collectors.

    t: traced slot index; q_mass: [3] queue mass by locality class;
    qlen: [M] (or [1] for FCFS) per-server queue lengths; workload: [M]
    per-server BP workload or None (families without a workload metric);
    probe: (rank_sum, regret_sum, n_decisions) floats.
    """
    w = jnp.minimum(t // tcfg.window_len(T), tcfg.n_windows - 1)
    rank_s, regret_s, probe_n = probe
    f = jnp.float32
    if workload is None:
        w_mean = w_max = f(0.0)
    else:
        finite = jnp.where(jnp.isfinite(workload), workload, 0.0)
        w_mean = finite.mean()
        w_max = finite.max()
    q_mass = jnp.asarray(q_mass, jnp.float32)
    row = jnp.stack([
        f(1.0), f(N), q_mass[0], q_mass[1], q_mass[2], f(completions),
        f(busy_n), f(arrivals), f(clipped), w_mean, w_max,
        f(rank_s), f(regret_s), f(probe_n)])
    win = tele.win.at[w].add(row)
    win_max = tele.win_max.at[w].max(jnp.stack([f(N), w_max]))
    qbins = bin_index(qlen, tcfg.n_bins, tcfg.bins_per_octave)
    qlen_hist = tele.qlen_hist.at[w, qbins].add(1.0)
    work_hist = tele.work_hist
    if workload is not None:
        wbins = bin_index(jnp.where(jnp.isfinite(workload), workload, 0.0),
                          tcfg.n_bins, tcfg.bins_per_octave)
        work_hist = work_hist.at[w, wbins].add(1.0)
    return tele._replace(win=win, win_max=win_max, qlen_hist=qlen_hist,
                         work_hist=work_hist)


# ---------------------------------------------------------------------------
# Sojourn rings: per-queue FIFOs of arrival slots, mirrored on the queue
# counts the simulator already keeps.  Pushes happen at routing, pops at
# service start, the histogram record at completion — exactly refsim's
# per-task bookkeeping, in static shapes.
# ---------------------------------------------------------------------------


def ring_push(tele: Telemetry, tcfg: TelemetryConfig, qid: jnp.ndarray,
              mask: jnp.ndarray, t) -> Telemetry:
    """Append arrival slot ``t`` to the FIFO of queue ``qid[a]`` for every
    valid arrival of a slot's batch.  Same-queue arrivals within the batch
    are ranked by batch position (O(A^2) one-hot comparison — A = a_max is
    small) so each lands in its own ring slot.  A queue whose ring is full
    drops the record (counted; the queue itself is NOT affected)."""
    if tele.ring is None:
        return tele
    cap = tcfg.ring_cap
    nq = tele.ring.shape[0] - 1
    A = qid.shape[0]
    i = jnp.arange(A)
    same_before = ((qid[None, :] == qid[:, None]) & mask[None, :]
                   & (i[None, :] < i[:, None]))
    rank = same_before.sum(axis=1)
    nrec = tele.tail[qid] - tele.head[qid]
    ok = mask & (nrec + rank < cap)
    qd = jnp.where(ok, qid, nq)                      # dummy row absorbs
    pos = jnp.where(ok, (tele.tail[qid] + rank) % cap, 0)
    ring = tele.ring.at[qd, pos].set(jnp.int32(t))
    tail = tele.tail.at[qd].add(1)
    tail = tail.at[nq].set(0)                        # keep dummy row inert
    dropped = tele.sojourn_dropped + (mask & ~ok).sum().astype(jnp.float32)
    return tele._replace(ring=ring, tail=tail, sojourn_dropped=dropped)


def ring_pop(tele: Telemetry, tcfg: TelemetryConfig, qid: jnp.ndarray,
             do_pop: jnp.ndarray, server: jnp.ndarray) -> Telemetry:
    """Pop the head arrival slot of queue ``qid[s]`` for every granted
    service start and stamp it into ``cur_arr[server[s]]``.  Multiple
    claimants on one queue (SQ steal conflicts) are ranked by claimant
    position.  A queue with no records (post-overflow) yields -1 — that
    task's sojourn is skipped, never misattributed as 0."""
    if tele.ring is None:
        return tele
    cap = tcfg.ring_cap
    nq = tele.ring.shape[0] - 1
    P = qid.shape[0]
    i = jnp.arange(P)
    same_before = ((qid[None, :] == qid[:, None]) & do_pop[None, :]
                   & (i[None, :] < i[:, None]))
    rank = same_before.sum(axis=1)
    nrec = tele.tail[qid] - tele.head[qid]
    ok = do_pop & (rank < nrec)
    arr = tele.ring[qid, (tele.head[qid] + rank) % cap]
    arr = jnp.where(ok, arr, -1)
    qd = jnp.where(ok, qid, nq)
    head = tele.head.at[qd].add(1)
    head = head.at[nq].set(0)
    cur_arr = tele.cur_arr.at[server].set(
        jnp.where(do_pop, arr, tele.cur_arr[server]))
    return tele._replace(head=head, cur_arr=cur_arr)


def record_sojourns(tele: Telemetry, tcfg: TelemetryConfig, t, warmup: int,
                    completed: jnp.ndarray) -> Telemetry:
    """At completion, sojourn = t - arrival slot of the in-service task.
    Recorded only when the task arrived after warmup (refsim's measurement
    condition: ``t >= warmup and started_at[m] >= warmup``)."""
    if tele.cur_arr is None:
        return tele
    s = jnp.int32(t) - tele.cur_arr
    valid = completed & (tele.cur_arr >= warmup)
    b = bin_index(s, tcfg.n_bins, tcfg.bins_per_octave)
    hist = tele.sojourn_hist.at[b].add(valid.astype(jnp.float32))
    return tele._replace(sojourn_hist=hist)


# ---------------------------------------------------------------------------
# Probe quality (rank / regret of pod decisions vs the O(M) optimum)
# ---------------------------------------------------------------------------


def probe_stats_min(full_scores: jnp.ndarray, chosen: jnp.ndarray,
                    valid: jnp.ndarray):
    """(rank_sum, regret_sum, n) for arg-MIN decisions.

    full_scores: [..., M] scores of every server the O(M) policy would
    examine (+inf = ineligible); chosen: [...] the pod decision's own
    score; valid: [...] decision mask.  rank = count of strictly better
    servers (0 = pod found a global optimum); regret = chosen - min.
    """
    best = jnp.min(full_scores, axis=-1)
    rank = (full_scores < chosen[..., None]).sum(axis=-1)
    regret = chosen - best
    regret = jnp.where(jnp.isfinite(regret), regret, 0.0)
    v = valid.astype(jnp.float32)
    return ((rank * v).sum(), (jnp.maximum(regret, 0.0) * v).sum(), v.sum())


def probe_stats_max(full_scores: jnp.ndarray, chosen: jnp.ndarray,
                    valid: jnp.ndarray, eligible: jnp.ndarray):
    """(rank_sum, regret_sum, n) for arg-MAX decisions (JSQ-MaxWeight
    scheduling).  eligible masks the (server, queue) pairs the O(M) policy
    may pick; regret = max - chosen."""
    masked = jnp.where(eligible, full_scores, -jnp.inf)
    best = jnp.max(masked, axis=-1)
    rank = (eligible & (full_scores > chosen[..., None])).sum(axis=-1)
    regret = best - chosen
    regret = jnp.where(jnp.isfinite(regret), regret, 0.0)
    v = valid.astype(jnp.float32)
    return ((rank * v).sum(), (jnp.maximum(regret, 0.0) * v).sum(), v.sum())


ZERO_PROBE = (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0))
