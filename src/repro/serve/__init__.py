from .engine import EngineStats, Request, ServeEngine
