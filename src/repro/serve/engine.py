"""Serving engine: batched decode over replica groups, requests routed by
the paper's Balanced-Pandas-Pod (repro.sched.PodRouter).

The engine is deliberately two-layer:
  - token generation is REAL (jit'd decode_step on the supplied model), so
    examples/serve_pod_router.py produces actual tokens;
  - the locality cost model is the paper's: a request served by a replica
    that holds its prefix (local) starts decoding immediately; same-pod
    (rack-local) pays an ICI-fetch delay; other-pod (remote) pays the DCN/
    recompute delay — delays expressed in engine ticks, mirroring the
    alpha/beta/gamma service rates of repro.sched.locality.

Metrics: per-request completion time (arrival -> last token), locality mix,
router probes per decision (the paper's O(M) vs O(1) complexity axis),
per-tick queue-depth / batch-size traces, and latency p50/p95 read from
the shared log-spaced histogram convention (repro.telemetry.hist) — the
same bins the simulator's in-jit collectors use, so serving and simulation
latency distributions are directly comparable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_cache, logits_fn
from ..sched.locality import FleetTopology
from ..sched.router import PodRouter
from ..telemetry.hist import np_hist, percentiles


@dataclasses.dataclass
class Request:
    rid: int
    prefix_id: int
    prompt: np.ndarray             # [P] int32
    max_new: int
    arrival: int
    replica: int = -1
    cls: int = -1
    start_tick: int = -1
    done_tick: int = -1
    generated: Optional[list] = None


@dataclasses.dataclass
class EngineStats:
    completions: list
    locality: np.ndarray
    probes_per_decision: float
    # observability (PR 6): per-tick traces + histogram-derived latency
    queue_depth_trace: Optional[np.ndarray] = None   # [ticks] waiting reqs
    batch_size_trace: Optional[np.ndarray] = None    # [ticks] active reqs
    latency_hist: Optional[np.ndarray] = None        # telemetry.hist bins
    latency_p50: float = float("nan")
    latency_p95: float = float("nan")
    note: Optional[str] = None     # set when percentiles are NaN (and why)


class ServeEngine:
    """One engine tick == one decode token per active request (plus any
    locality fetch delay before a request's first token)."""

    FETCH_TICKS = {0: 0, 1: 4, 2: 16}     # local / rack (ICI) / remote (DCN)

    def __init__(self, cfg, params, fleet: FleetTopology, router: PodRouter,
                 prefix_homes: dict, max_batch: int = 8, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.fleet = fleet
        self.router = router
        self.prefix_homes = prefix_homes     # prefix_id -> [replica ids]
        self.max_batch = max_batch
        self.active: dict[int, list[Request]] = {
            r: [] for r in range(fleet.n_replicas)}
        self.waiting: dict[int, list[Request]] = {
            r: [] for r in range(fleet.n_replicas)}
        self.tick = 0
        self.done: list[Request] = []
        self._queue_depth_trace: list[int] = []
        self._batch_size_trace: list[int] = []
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg=cfg))
        self.rng = np.random.default_rng(seed)

    @staticmethod
    def _decode_impl(params, cache, tok, pos, cfg):
        h, cache = decode_step(params, cfg, cache, tok, pos)
        logits = logits_fn(params["embed"], h)[:, 0]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    # ------------------------------------------------------------------

    def submit(self, reqs: list[Request]):
        homes = np.stack([self.prefix_homes[r.prefix_id] for r in reqs])
        chosen = self.router.route(homes)
        for r, rep in zip(reqs, chosen):
            r.replica = int(rep)
            r.cls = int(0 if rep in self.prefix_homes[r.prefix_id]
                        else 1 if self.fleet.pod_of(rep) in
                        {self.fleet.pod_of(h) for h in
                         self.prefix_homes[r.prefix_id]} else 2)
            r.start_tick = self.tick + self.FETCH_TICKS[r.cls]
            r.generated = []
            self.waiting[r.replica].append(r)

    def step(self):
        """One tick: admit fetch-complete requests, decode one token for
        every active request on every replica (one real batched decode per
        replica), retire finished requests."""
        self.tick += 1
        self._queue_depth_trace.append(
            sum(len(q) for q in self.waiting.values()))
        self._batch_size_trace.append(
            sum(len(b) for b in self.active.values()))
        for rep in range(self.fleet.n_replicas):
            admit = [r for r in self.waiting[rep]
                     if r.start_tick <= self.tick
                     and len(self.active[rep]) < self.max_batch]
            for r in admit:
                self.waiting[rep].remove(r)
                self.active[rep].append(r)
            batch = self.active[rep]
            if not batch:
                continue
            B = len(batch)
            # real decode: feed last token of each request's stream
            toks = np.array([[r.prompt[-1] if not r.generated
                              else r.generated[-1]] for r in batch],
                            np.int32)
            pos = np.array([len(r.prompt) + len(r.generated) - 1
                            for r in batch], np.int32)
            S = int(max(pos.max() + 2, 16))
            cache = init_cache(self.cfg, B, S)
            nxt, _ = self._decode(self.params, cache, jnp.asarray(toks),
                                  jnp.asarray(pos))
            finished = []
            for r, t in zip(batch, np.asarray(nxt)):
                r.generated.append(int(t))
                if len(r.generated) >= r.max_new:
                    r.done_tick = self.tick
                    finished.append(r)
            for r in finished:
                self.active[rep].remove(r)
                self.router.complete(np.array([r.replica]),
                                     np.array([r.cls]))
                self.done.append(r)

    def run(self, until_done: int, max_ticks: int = 100_000) -> EngineStats:
        while len(self.done) < until_done and self.tick < max_ticks:
            self.step()
        return self._stats()

    def run_arrivals(self, schedule, make_request,
                     max_ticks: int = 100_000) -> EngineStats:
        """Replay a scenario-driven arrival trace: ``schedule[i]`` requests
        are submitted at tick i (e.g. repro.scenarios.arrival_counts for
        MMPP / diurnal / flash-crowd traffic shapes), then drain.

        make_request(arrival_tick) -> Request (with ``arrival`` set)."""
        total = int(np.sum(schedule))
        i = 0
        while (i < len(schedule) or len(self.done) < total) \
                and self.tick < max_ticks:
            if i < len(schedule):
                n = int(schedule[i])
                if n:
                    self.submit([make_request(self.tick) for _ in range(n)])
                i += 1
            self.step()
        return self._stats()

    def _stats(self) -> EngineStats:
        comp = [r.done_tick - r.arrival for r in self.done]
        loc = np.bincount([r.cls for r in self.done], minlength=3)
        probes = (self.router.stats.probes
                  / max(self.router.stats.decisions, 1))
        hist = np_hist(comp) if comp else None
        p50 = p95 = float("nan")
        note = None
        if hist is not None:
            p50, p95 = percentiles(hist, (50, 95))
        if not np.isfinite(p50) or not np.isfinite(p95):
            note = (f"zero completions in {self.tick} ticks: latency "
                    f"p50/p95 are NaN (not 0 — nothing finished)")
            print(f"[serve] NOTE: {note}")
        return EngineStats(
            completions=comp, locality=loc / max(len(self.done), 1),
            probes_per_decision=probes,
            queue_depth_trace=np.asarray(self._queue_depth_trace, np.int64),
            batch_size_trace=np.asarray(self._batch_size_trace, np.int64),
            latency_hist=hist, latency_p50=p50, latency_p95=p95, note=note)
