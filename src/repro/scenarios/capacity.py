"""Placement-aware capacity edge: the fluid LP behind ``lam_cap``.

The closed form in :func:`build.capacity_scale` prices the fleet axis only
(time-averaged LOCAL speeds: every task is assumed servable locally at the
boundary).  That is exact for uniform placement — random replica triples
spread demand so thinly that local capacity never binds — but under a
Zipf-skewed or adversarial catalog the hot chunks saturate their few local
servers long before ``lam = alpha * sum_m speed_m``, and the spill-over is
served at the slower beta/gamma tiers.  The honest edge is the optimum of
the fluid LP over per-(chunk, server) flow rates (GB-PANDAS, arXiv
1709.08115; the three-locality-level model of arXiv 1702.07802):

    maximize   lam
    subject to sum_s w_s * sum_m mu_s[c, m] * x[s, c, m]  >=  lam * pbar_c
               sum_c x[s, c, m]  <=  1          for every (segment s, server m)
               0 <= x <= 1,  lam >= 0

where ``x[s, c, m]`` is the fraction of server m's time spent on chunk c
during speed segment s, ``mu_s[c, m] = rates[g] * speed_s[m, g]`` with
``g = locality_class(c, m)`` (LOCAL if m holds a replica, RACK if m shares
a rack with one, REMOTE otherwise), ``w_s`` the segment's share of the run,
and ``pbar_c`` chunk c's time-averaged popularity (churn epochs weighted by
their slot counts).  Queues buffer across segments and epochs, so demand
and capacity both integrate over the run — the same time-averaged stance
``capacity_scale`` already takes for speed windows.

``capacity_edge`` is the dispatcher ``build.realize`` calls: uniform
placement keeps the closed form bit-for-bit (fast path + the historical
contract), skewed catalogs get the LP optimum.  Everything here is
host-side numpy/scipy — nothing runs under jit, so the one-compile sweep
invariant is untouched.  Results are memoized on array content: realizing
the same scenario repeatedly (canonical_a_max, stack_scenarios, grids)
solves each LP once per process.

Requires scipy (HiGHS via ``scipy.optimize.linprog``).  Without scipy the
module falls back to the closed form with a loud one-time warning — edges
for skewed placements are then optimistic, exactly the pre-LP behavior.
"""
from __future__ import annotations

import hashlib
import warnings
from typing import TYPE_CHECKING

import numpy as np

from .build import ScenarioData, capacity_scale

if TYPE_CHECKING:  # runtime import would cycle through repro.core.simulator
    from ..core.cluster import Cluster, Rates

try:  # scipy is a default dependency but everything degrades without it
    from scipy import sparse as _sparse
    from scipy.optimize import linprog as _linprog

    HAVE_SCIPY = True
except Exception:  # pragma: no cover - exercised only on scipy-less hosts
    _sparse = _linprog = None
    HAVE_SCIPY = False

_LOCAL, _RACK, _REMOTE = 0, 1, 2      # mirror core.cluster (import would cycle)

_EDGE_CACHE: dict = {}
_EDGE_CACHE_MAX = 256

_warned_no_scipy = False


def uniform_edge(scen: ScenarioData, rates: "Rates", T: int) -> float:
    """The fleet-axis closed form: ``alpha * M * capacity_scale`` — exact
    for uniform placement and bit-for-bit the pre-LP ``lam_cap``."""
    return rates.alpha * scen.M * capacity_scale(scen, T)


def speed_segments(scen: ScenarioData, T: int) -> list:
    """``[(slots, speed [M, 3] float64), ...]`` — the run as piecewise-
    constant speed segments (windows make speed piecewise constant), with
    identical-speed segments merged (their slot counts add; allocation in
    the LP is per distinct speed matrix, not per calendar interval)."""
    start = np.asarray(scen.win_start, np.int64)
    end = np.asarray(scen.win_end, np.int64)
    bounds = np.unique(np.clip(np.concatenate(
        [[0, T], start, end]), 0, T)).astype(np.int64)
    base = np.asarray(scen.base_speed, np.float64)[:, None]      # [M, 1]
    mult = np.asarray(scen.win_mult, np.float64)                 # [E, M, 3]
    segs: dict = {}
    order = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi <= lo:
            continue
        active = (start <= lo) & (lo < end)                      # [E]
        sp = base * np.where(active[:, None, None], mult, 1.0).prod(axis=0)
        key = sp.tobytes()
        if key not in segs:
            segs[key] = [0, sp]
            order.append(key)
        segs[key][0] += int(hi - lo)
    return [(segs[k][0], segs[k][1]) for k in order]


def chunk_demand(scen: ScenarioData, T: int):
    """``(pbar [C] float64, locals [C, n_rep] int64)`` — each chunk's
    time-averaged popularity (churn epochs weighted by their slot counts;
    epoch rows are CONDITIONAL popularity while active) and replica triple.
    Pad rows (_PAD_LOGIT) underflow to exactly 0 popularity."""
    locals_ = np.asarray(scen.chunk_locals, np.int64)
    if scen.epoch_logits is not None:
        elog = np.asarray(scen.epoch_logits, np.float64)         # [P, C]
        P = elog.shape[0]
        if scen.placement_epoch is not None:
            pe = np.asarray(scen.placement_epoch)
            counts = np.bincount(pe, minlength=P).astype(np.float64)
        else:
            counts = np.zeros(P)
            counts[0] = float(T)
        with np.errstate(under="ignore"):
            p = np.exp(elog)
        norm = p.sum(axis=1, keepdims=True)
        p = np.divide(p, norm, out=np.zeros_like(p), where=norm > 0)
        pbar = (counts[:, None] / float(T) * p).sum(axis=0)
    else:
        with np.errstate(under="ignore"):
            pbar = np.exp(np.asarray(scen.chunk_logits, np.float64))
        pbar = pbar / max(pbar.sum(), 1e-300)
    return pbar, locals_


def _locality_classes(locals_: np.ndarray, M: int, K: int) -> np.ndarray:
    """[G, M] int8 locality class of every (chunk group, server) pair."""
    R = M // K
    rack_of = np.arange(M) // R
    G = locals_.shape[0]
    cls = np.full((G, M), _REMOTE, np.int8)
    for g in range(G):
        locs = locals_[g]
        cls[g, np.isin(rack_of, np.unique(locs // R))] = _RACK
        cls[g, locs] = _LOCAL
    return cls


def fluid_edge(scen: ScenarioData, cluster: "Cluster", rates: "Rates",
               T: int) -> float:
    """Solve the fluid LP (module docstring) and return its optimum —
    the largest total arrival rate (tasks/slot) for which per-chunk demand
    fits inside the per-(segment, server) time budget.  Host-side only;
    raises RuntimeError if HiGHS reports anything but an optimal solution
    and ImportError when scipy is unavailable."""
    if not HAVE_SCIPY:  # pragma: no cover - exercised only without scipy
        raise ImportError("fluid_edge needs scipy (scipy.optimize.linprog)")
    pbar, locals_ = chunk_demand(scen, T)
    # chunks sharing a replica triple are interchangeable in every
    # constraint: merge them (their demands add) before sizing the LP
    trip = np.sort(locals_, axis=1)
    uniq, inv = np.unique(trip, axis=0, return_inverse=True)
    pbar_g = np.zeros(uniq.shape[0])
    np.add.at(pbar_g, inv, pbar)
    live = pbar_g > 1e-15                    # pad rows carry exactly 0 mass
    uniq, pbar_g = uniq[live], pbar_g[live]
    total = pbar_g.sum()
    if total <= 0:
        # an all-pad catalog is a uniform scenario in disguise
        return uniform_edge(scen, rates, T)
    pbar_g = pbar_g / total
    G = uniq.shape[0]
    M = cluster.M
    segs = speed_segments(scen, T)
    S = len(segs)
    cls = _locality_classes(uniq, M, cluster.K)                  # [G, M]
    rates_arr = np.array([rates.alpha, rates.beta, rates.gamma], np.float64)

    # variables: z = [lam, x_0 .. x_{n-1}]; only (s, g, m) with mu > 0
    rows, cols, vals = [], [], []
    next_var = 1
    cap_ub = 0.0                     # sum of best-class service rates: lam ub
    midx = np.arange(M)
    for s, (slots, sp) in enumerate(segs):
        w = slots / float(T)
        sp_cls = sp[midx[None, :], cls]                          # [G, M]
        mu = rates_arr[cls] * sp_cls                             # [G, M]
        cap_ub += w * (rates_arr[None, :, None]
                       * sp.T[None, :, :]).max(axis=(0, 1)).sum()
        gi, mi = np.nonzero(mu > 0)
        n = gi.size
        ids = next_var + np.arange(n)
        next_var += n
        # demand rows (one per group): -(w * mu) * x
        rows.append(gi)
        cols.append(ids)
        vals.append(-w * mu[gi, mi])
        # server-time rows (one per (segment, server)): + x <= 1
        rows.append(G + s * M + mi)
        cols.append(ids)
        vals.append(np.ones(n))
    # lam column in every demand row: + pbar_g * lam <= served mass
    rows.append(np.arange(G))
    cols.append(np.zeros(G, np.int64))
    vals.append(pbar_g)
    n_vars = next_var
    n_rows = G + S * M
    A = _sparse.coo_matrix(
        (np.concatenate(vals), (np.concatenate(rows), np.concatenate(cols))),
        shape=(n_rows, n_vars)).tocsr()
    b = np.concatenate([np.zeros(G), np.ones(S * M)])
    c = np.zeros(n_vars)
    c[0] = -1.0                                  # maximize lam
    bounds = np.ones((n_vars, 2))
    bounds[:, 0] = 0.0
    bounds[0, 1] = max(cap_ub, 1e-12)
    res = _linprog(c, A_ub=A, b_ub=b, bounds=bounds, method="highs")
    if not res.success:  # pragma: no cover - defensive; LP is always feasible
        raise RuntimeError(
            f"capacity LP failed ({res.status}: {res.message}) — "
            f"G={G} groups, M={M} servers, {S} segments")
    return max(0.0, float(-res.fun))


def _is_uniform(scen: ScenarioData) -> bool:
    """True when the scenario places uniformly (no catalog, or a canonical
    padding whose data-selected law is the uniform branch)."""
    if scen.chunk_locals is None or scen.chunk_logits is None:
        return True
    if scen.placement_on is not None and \
            float(np.asarray(scen.placement_on)) == 0.0:
        return True
    return False


def _cache_key(scen: ScenarioData, cluster: "Cluster", rates: "Rates",
               T: int) -> bytes:
    h = hashlib.sha1()
    h.update(np.int64([T, cluster.M, cluster.K, cluster.n_replicas]).tobytes())
    h.update(np.float64([rates.alpha, rates.beta, rates.gamma]).tobytes())
    for a in (scen.base_speed, scen.win_start, scen.win_end, scen.win_mult,
              scen.chunk_logits, scen.chunk_locals, scen.epoch_logits,
              scen.placement_epoch):
        h.update(b"|" if a is None else np.asarray(a).tobytes())
    return h.digest()


def capacity_edge(scen: ScenarioData, cluster: "Cluster", rates: "Rates",
                  T: int) -> float:
    """The scenario's capacity-region edge ``lam_cap`` (tasks/slot at
    load 1) — what ``build.realize`` returns and every ``load`` knob in the
    repo is a fraction of.

    Uniform placement takes the closed-form fast path (bit-for-bit the
    pre-LP value; the LP reproduces it — see tests' regression identity);
    skewed catalogs get the fluid-LP optimum, which is strictly smaller
    whenever a hot chunk's demand overflows its local tier at the fleet
    edge.  Memoized on array content, so repeated realizations (grids,
    stacked sweeps, canonical_a_max) solve each LP once per process."""
    if _is_uniform(scen):
        return uniform_edge(scen, rates, T)
    if not HAVE_SCIPY:  # pragma: no cover - exercised only without scipy
        global _warned_no_scipy
        if not _warned_no_scipy:
            _warned_no_scipy = True
            warnings.warn(
                "scipy unavailable: capacity_edge falls back to the "
                "fleet-only closed form — lam_cap is OPTIMISTIC for "
                "Zipf/adversarial placements (install scipy for the "
                "fluid-LP edge)", RuntimeWarning, stacklevel=2)
        return uniform_edge(scen, rates, T)
    key = _cache_key(scen, cluster, rates, T)
    hit = _EDGE_CACHE.get(key)
    if hit is not None:
        return hit
    val = fluid_edge(scen, cluster, rates, T)
    if len(_EDGE_CACHE) >= _EDGE_CACHE_MAX:
        _EDGE_CACHE.pop(next(iter(_EDGE_CACHE)))
    _EDGE_CACHE[key] = val
    return val
