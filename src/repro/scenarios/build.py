"""Realize a Scenario spec into arrays the jit'd simulator scans over.

Contract
--------
``realize(scenario, cluster, rates, T, pad=None)`` turns a declarative
:class:`~repro.scenarios.spec.Scenario` into a :class:`ScenarioData`
pytree of concrete arrays (shapes documented on the class) plus the
scenario's capacity-region edge ``lam_cap`` (tasks/slot at load 1).
Realization is deterministic in ``scenario.seed`` and host-side only —
nothing here runs under jit; the simulator scans over the returned
arrays.

Single-compile invariants
-------------------------
Two knobs keep a whole sweep on ONE compiled simulator signature:

* ``pad`` (:class:`ScenarioPad`, usually :func:`canonical_pad`): pads
  window/catalog/epoch arrays to registry-wide maxima and switches the
  placement law to data-selection (``placement_on``), so every scenario
  shares one pytree structure and one set of leaf shapes.
* ``canonical_a_max``: one arrival-buffer width (a static jit argument)
  sized from the PEAK slot intensity over the whole sweep.

``stack_scenarios`` builds on both: it realizes many scenarios against
one pad and stacks them along a leading ``[S]`` axis — the input the
batched sweep engine (``core.simulate_sweep``) vmaps and shard_maps over.

All float arrays are float32 (except host-side capacity integration,
float64); index arrays are int32.
"""
from __future__ import annotations

import math
from typing import TYPE_CHECKING, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .spec import (
    COMPOSE_DEPTH,
    SCENARIOS,
    FleetSpec,
    PlacementSpec,
    Scenario,
    TrafficSpec,
    WindowSpec,
    get_scenario,
    registry_limits,
)

if TYPE_CHECKING:  # runtime import would cycle: core.simulator imports us
    from ..core.cluster import Cluster, Rates


class ScenarioData(NamedTuple):
    """Pytree of realized scenario arrays (dynamic jit operands).

    lam_shape     [T]  arrival-intensity shape, mean ~1 (multiplies lambda)
    base_speed    [M]  persistent per-server speed multipliers
    win_start/end [E]  event-window slot bounds (E may be 0)
    win_mult      [E, M, 3] per-window, per-locality-class speed multiplier
                  (1.0 = unaffected).  Whole-server windows carry equal
                  columns; per-class windows (network-tier degradation,
                  ToR cascades) scale the beta/gamma columns independently.
    chunk_logits  [C]  log chunk popularity, or None for uniform placement
    chunk_locals  [C, n_replicas] each chunk's replica triple, or None
    size_mu       scalar lognormal log-mean of the per-task service-size
                  multiplier (None on directly-constructed pytrees; realize
                  always emits it, with mu = -sigma^2/2 so the multiplier
                  has mean exactly 1 and lam_cap is size-law invariant)
    size_sigma    scalar lognormal log-std; 0.0 (the registry default)
                  leaves sampled durations untouched bit-for-bit
    placement_on  scalar 0/1 selector, or None.  Canonical (padded)
                  realizations always carry the chunk arrays and choose the
                  placement law by DATA instead of pytree structure:
                  1.0 -> draw from the chunk catalog, 0.0 -> uniform
                  sample_locals.  That keeps every scenario on one compiled
                  signature (the one-compile sweep).  None preserves the
                  unpadded behavior, where structure picks the law.
    epoch_logits  [P, C] per-churn-epoch chunk popularity (trace-lowered
                  placements: row e is the CONDITIONAL popularity while
                  epoch e is active, so per-instant skew is not diluted by
                  mixing epochs), or None (single-epoch placements; the
                  global ``chunk_logits`` law applies at every slot).
                  Canonical realizations always carry it — row 0 mirrors
                  chunk_logits, pad rows get ~ -inf.
    placement_epoch  [T] int32 slot -> churn-epoch index into epoch_logits
                  (zeros for single-epoch placements), or None.
    """

    lam_shape: jnp.ndarray
    base_speed: jnp.ndarray
    win_start: jnp.ndarray
    win_end: jnp.ndarray
    win_mult: jnp.ndarray
    chunk_logits: Optional[jnp.ndarray]
    chunk_locals: Optional[jnp.ndarray]
    placement_on: Optional[jnp.ndarray] = None
    size_mu: Optional[jnp.ndarray] = None
    size_sigma: Optional[jnp.ndarray] = None
    epoch_logits: Optional[jnp.ndarray] = None
    placement_epoch: Optional[jnp.ndarray] = None

    @property
    def M(self) -> int:
        """Number of servers this realization was built for."""
        return self.base_speed.shape[0]


class ScenarioPad(NamedTuple):
    """Canonical array shapes every realized scenario is padded to.

    n_windows: event-window slots (inactive pads: start == end == 0,
    mult == 1).  n_chunks: placement-catalog rows (pads get ~ -inf logits,
    so they are never drawn).  Realizing every scenario of a sweep with the
    same ScenarioPad makes all ScenarioData pytrees share one structure and
    one set of leaf shapes — the jit'd simulator then traces exactly once
    for the whole sweep.
    """

    n_windows: int
    n_chunks: int
    n_epochs: int = 1


def canonical_pad(cluster: "Cluster", scenarios=None,
                  compose_depth: Optional[int] = None) -> ScenarioPad:
    """The registry-wide ScenarioPad (or for an explicit scenario subset).

    compose_depth widens the event-window budget for deeper-than-pairwise
    ``compose()`` products (default: spec.COMPOSE_DEPTH = 2).  A 3-way
    product of window-carrying scenarios overflows the default pad —
    ``realize`` / ``stack_scenarios`` reject it with a ValueError naming
    ``canonical_pad(..., compose_depth=3)`` as the fix."""
    n_windows, chunks_per_server, n_epochs = registry_limits(
        scenarios, compose_depth=compose_depth)
    return ScenarioPad(n_windows=max(n_windows, 1),
                       n_chunks=max(chunks_per_server * cluster.M, 1),
                       n_epochs=max(n_epochs, 1))


def canonical_a_max(cluster: "Cluster", rates: "Rates", cfg, load: float,
                    scenarios=None) -> int:
    """One arrival-batch width valid for every scenario in the sweep.

    ``a_max`` is a static jit argument of the simulator, so a per-scenario
    value (peak intensity x scenario capacity) would force one recompile per
    scenario even with canonical array padding.  This resolves the maximum
    over the registry (or an explicit subset), sizing each scenario's
    buffer from its PEAK slot intensity (mean rate x max of the mean-1
    intensity shape — flash/diurnal shapes spike well above the mean); cfg
    is any object with ``T`` and ``resolve_a_max(lam, shape_peak)`` (i.e.
    a core.SimConfig — duck-typed to avoid an import cycle).
    """
    specs = tuple(scenarios) if scenarios is not None else tuple(
        SCENARIOS.values())
    a_max = 1
    for s in specs:
        scen, lam_cap = realize(get_scenario(s), cluster, rates, cfg.T)
        shape_peak = float(np.max(np.asarray(scen.lam_shape)))
        a_max = max(a_max, cfg.resolve_a_max(float(load) * lam_cap,
                                             shape_peak))
    return a_max


def speed_at(scen: ScenarioData, t) -> jnp.ndarray:
    """[M, 3] effective per-class speed at slot ``t`` (jit-safe; t may be
    traced).  Column c scales the class-c service rate; whole-server
    windows carry equal columns.  Windows compose multiplicatively when
    they overlap."""
    active = (scen.win_start <= t) & (t < scen.win_end)          # [E]
    mult = jnp.where(active[:, None, None], scen.win_mult, 1.0)  # [E, M, 3]
    return scen.base_speed[:, None] * jnp.prod(mult, axis=0)


def speed_trace(scen: ScenarioData, T: int) -> np.ndarray:
    """[T, M, 3] host-side speed trace (tests / plots; not the hot path)."""
    start = np.asarray(scen.win_start)[None, :]                  # [1, E]
    end = np.asarray(scen.win_end)[None, :]
    t = np.arange(T)[:, None]                                    # [T, 1]
    active = (start <= t) & (t < end)                            # [T, E]
    mult = np.where(active[:, :, None, None],
                    np.asarray(scen.win_mult)[None], 1.0)        # [T, E, M, 3]
    return np.asarray(scen.base_speed)[None, :, None] * mult.prod(axis=1)


# ---------------------------------------------------------------------------
# Fleet axis
# ---------------------------------------------------------------------------


def _check_rack(r: int, cluster: "Cluster", w: WindowSpec) -> None:
    # loud, not silent: an out-of-range rack would otherwise realize as an
    # all-False mask — an inert window, i.e. a failure event that never
    # happens (generators hard-code rack counts; see generators.py)
    if not 0 <= r < cluster.K:
        raise ValueError(f"window {w} targets rack {r}, but the cluster "
                         f"has K={cluster.K} racks")


def _window_mask(w: WindowSpec, cluster: "Cluster") -> np.ndarray:
    m = np.arange(cluster.M)
    if w.rack is not None:
        _check_rack(w.rack, cluster, w)
        return (m // cluster.rack_size) == w.rack
    if w.servers is not None:
        lo, hi = w.servers
        return (m >= lo) & (m < hi)
    if w.every is not None:
        return (m % w.every) == w.phase
    if w.rack_member is not None:
        r, i = w.rack_member
        _check_rack(r, cluster, w)
        return m == r * cluster.rack_size + (i % cluster.rack_size)
    raise ValueError(f"window {w} selects no servers")


def _fleet_arrays(fleet: FleetSpec, cluster: "Cluster", T: int,
                  rng: np.random.Generator):
    M = cluster.M
    base = np.ones(M, np.float32)
    for r, s in enumerate(fleet.rack_speeds):
        base[r * cluster.rack_size:(r + 1) * cluster.rack_size] = s
    for frac, s_mult in fleet.cohorts():
        k = max(1, int(round(frac * M)))
        base[rng.choice(M, size=k, replace=False)] *= s_mult
    E = len(fleet.windows)
    start = np.zeros(E, np.int32)
    end = np.zeros(E, np.int32)
    mult = np.ones((E, M, 3), np.float32)
    for e, w in enumerate(fleet.windows):
        start[e] = int(round(w.t0 * T))
        end[e] = int(round(w.t1 * T))
        mult[e, _window_mask(w, cluster)] = np.asarray(w.class_mult,
                                                      np.float32)
    return base, start, end, mult


def capacity_scale(scen: ScenarioData, T: int) -> float:
    """Time-averaged sum_m local_speed_t[m] / M: the heterogeneous capacity
    region edge relative to the symmetric M * alpha.  At the boundary every
    task is served locally, so only the LOCAL (alpha, class-0) column of the
    window multipliers matters — beta/gamma-only degradation leaves the
    edge untouched.  Exact: windows make speed piecewise-constant, so
    integrate over the boundary segments."""
    start = np.asarray(scen.win_start)
    end = np.asarray(scen.win_end)
    bounds = np.unique(np.clip(np.concatenate(
        [[0, T], start, end]), 0, T)).astype(np.int64)
    total = 0.0
    base = np.asarray(scen.base_speed, np.float64)
    mult = np.asarray(scen.win_mult, np.float64)[:, :, 0]   # local tier
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi <= lo:
            continue
        active = (start <= lo) & (lo < end)                      # [E]
        seg = base * np.where(active[:, None], mult, 1.0).prod(axis=0)
        total += float(seg.sum()) * (hi - lo)
    return total / (T * scen.M)


# ---------------------------------------------------------------------------
# Traffic axis
# ---------------------------------------------------------------------------


def _shape_one(spec: TrafficSpec, T: int,
               rng: np.random.Generator) -> np.ndarray:
    """[T] float64 raw intensity shape of a single factor, clamped >= 0."""
    if hasattr(spec, "realize_shape"):
        # duck-typed extension hook: trace-backed traffic (repro.trace)
        # bins recorded arrival timestamps instead of evaluating a formula
        return np.maximum(
            np.asarray(spec.realize_shape(T, rng), np.float64), 0.0)
    t = np.arange(T, dtype=np.float64)
    if spec.kind == "stationary":
        shape = np.ones(T)
    elif spec.kind == "diurnal":
        shape = 1.0 + spec.amp * np.sin(2.0 * math.pi * spec.cycles * t / T)
    elif spec.kind == "flash":
        shape = np.ones(T)
        shape[int(spec.t0 * T):int(spec.t1 * T)] = spec.peak
    elif spec.kind == "mmpp":
        # 2-state Markov chain simulated host-side; start from the
        # stationary distribution so warmup statistics are unbiased.
        p01, p10 = spec.p_enter, spec.p_exit
        pi_burst = p01 / max(p01 + p10, 1e-12)
        state = 1 if rng.random() < pi_burst else 0
        shape = np.empty(T)
        u = rng.random(T)
        for i in range(T):
            shape[i] = spec.burst if state else 1.0
            if state == 0 and u[i] < p01:
                state = 1
            elif state == 1 and u[i] < p10:
                state = 0
    else:
        raise ValueError(f"unknown traffic kind {spec.kind!r}")
    # clamp before multiplying/normalizing: amp > 1 diurnals would otherwise
    # produce negative intensities (invalid Poisson rates) instead of dead
    # zones — and two negative factors must not multiply into spurious load
    return np.maximum(shape, 0.0)


def traffic_shape(spec, T: int, rng: np.random.Generator) -> np.ndarray:
    """[T] float32 intensity shape, normalized to mean 1 over the run.

    ``spec`` is a TrafficSpec or a TrafficProduct (the compose() merge of
    several non-trivial shapes): factors are realized left to right against
    the shared rng and multiplied pointwise, then normalized to mean 1
    once.  Deterministic factors (stationary / diurnal / flash) therefore
    compose order-invariantly; stochastic ones (mmpp) consume rng draws in
    factor order."""
    shape = np.ones(T, np.float64)
    for part in (spec.parts or (spec,)):
        shape = shape * _shape_one(part, T, rng)
    shape = shape / max(shape.mean(), 1e-12)
    return shape.astype(np.float32)


def arrival_counts(spec, T: int, mean_per_tick: float,
                   seed: int = 0) -> np.ndarray:
    """[T] int64 Poisson arrival counts following the traffic shape — the
    scenario-driven arrival trace the serve engine replays."""
    rng = np.random.default_rng(seed)
    return rng.poisson(mean_per_tick * traffic_shape(spec, T, rng))


# ---------------------------------------------------------------------------
# Placement axis
# ---------------------------------------------------------------------------


def _placement_arrays(spec: PlacementSpec, cluster: "Cluster",
                      rng: np.random.Generator):
    """(chunk_logits [C], chunk_locals [C, n_rep], epoch_logits [P, C]) —
    the last is None for single-epoch placements."""
    if hasattr(spec, "realize_catalog"):
        # duck-typed extension hook: trace-backed placement (repro.trace)
        # derives the catalog from observed chunk ids + churn episodes
        logits, locals_, epoch_logits = spec.realize_catalog(cluster, rng)
        return (jnp.asarray(logits), jnp.asarray(locals_),
                None if epoch_logits is None else jnp.asarray(epoch_logits))
    if spec.kind == "uniform":
        return None, None, None
    if spec.kind != "zipf":
        raise ValueError(f"unknown placement kind {spec.kind!r}")
    C = spec.chunks_per_server * cluster.M
    popularity = np.arange(1, C + 1, dtype=np.float64) ** (-spec.zipf_s)
    logits = np.log(popularity / popularity.sum()).astype(np.float32)
    # each chunk's replica triple: distinct servers, uniform placement —
    # the *popularity* is skewed, not the placement itself (HDFS-style)
    order = np.argsort(rng.random((C, cluster.M)), axis=1)
    locals_ = order[:, :cluster.n_replicas].astype(np.int32)
    if spec.hot_rack is not None:
        # adversarial placement: the hot head of the catalog (Zipf rows are
        # already popularity-ordered) lives entirely inside one rack
        R = cluster.rack_size
        if not 0 <= spec.hot_rack < cluster.K:
            raise ValueError(f"hot_rack {spec.hot_rack} out of range for "
                             f"K={cluster.K} racks")
        if R < cluster.n_replicas:
            raise ValueError(f"rack_size {R} cannot host "
                             f"{cluster.n_replicas} distinct replicas")
        n_hot = max(1, min(C, math.ceil(spec.hot_frac * C)))
        members = spec.hot_rack * R + np.arange(R)
        horder = np.argsort(rng.random((n_hot, R)), axis=1)
        locals_[:n_hot] = members[
            horder[:, :cluster.n_replicas]].astype(np.int32)
    return jnp.asarray(logits), jnp.asarray(locals_), None


def placement_epoch_at(scen: Optional[ScenarioData], t):
    """Scalar churn-epoch index at slot ``t`` (jit-safe; 0 when the
    scenario has no time-varying placement)."""
    if scen is None or scen.placement_epoch is None:
        return 0
    return scen.placement_epoch[t]


def sample_locals_scenario(key: jax.Array, cluster: "Cluster",
                           scen: ScenarioData, batch: int,
                           pe=0) -> jnp.ndarray:
    """Replica triples for ``batch`` tasks under the scenario's placement.

    Uniform placement defers to core.cluster.sample_locals; Zipf placement
    draws a chunk from the popularity law and returns its fixed triple.
    ``pe`` (scalar, may be traced — see placement_epoch_at) selects the
    active churn epoch's conditional popularity row when the scenario
    carries ``epoch_logits``; single-epoch placements use the global law.
    Canonical (padded) realizations carry ``placement_on`` and select
    between the two laws by data — both draws are computed and a scalar
    jnp.where picks one, so uniform and skewed scenarios share one trace."""
    from ..core.cluster import sample_locals

    if scen.chunk_locals is None:
        return sample_locals(key, cluster, batch)
    logits = (scen.epoch_logits[pe] if scen.epoch_logits is not None
              else scen.chunk_logits)
    if scen.placement_on is None:
        cidx = jax.random.categorical(key, logits, shape=(batch,))
        return scen.chunk_locals[cidx]
    k_cat, k_uni = jax.random.split(key)
    cidx = jax.random.categorical(k_cat, logits, shape=(batch,))
    skewed = scen.chunk_locals[cidx]
    uniform = sample_locals(k_uni, cluster, batch)
    return jnp.where(scen.placement_on > 0, skewed, uniform)


# ---------------------------------------------------------------------------
# Scenario stacking (the batched mega-sweep's input)
# ---------------------------------------------------------------------------


def stack_scenarios(scenarios, cluster: "Cluster", rates: "Rates", T: int,
                    pad: Optional[ScenarioPad] = None):
    """Realize every scenario against ONE canonical pad and stack the
    resulting pytrees along a new leading axis.

    Returns ``(stacked, lam_caps)`` where ``stacked`` is a ScenarioData
    whose every leaf carries a leading ``[S]`` scenario axis and
    ``lam_caps`` is a float64 ``[S]`` array of capacity-region edges
    (tasks/slot at load 1) in the same order.  This is the input contract
    of ``core.simulate_sweep``: because all S realizations share one
    canonical signature (same ScenarioPad, hence identical leaf shapes and
    pytree structure), the whole stack can be vmapped over — and
    shard_mapped across devices — by a single compiled program.

    ``scenarios`` is an iterable of registered names and/or Scenario
    objects; ``pad`` defaults to the registry-wide ``canonical_pad`` so a
    stacked sweep shares its compiled signature with per-scenario
    canonical runs.  Raises if a realization escapes the shared structure
    (e.g. an ad-hoc composition exceeding the pad's window headroom).
    """
    if pad is None:
        pad = canonical_pad(cluster)
    scens, caps = [], []
    for s in scenarios:
        scen, cap = realize(get_scenario(s), cluster, rates, T, pad=pad)
        scens.append(scen)
        caps.append(cap)
    if not scens:
        raise ValueError("stack_scenarios: empty scenario list")
    ref = jax.tree_util.tree_structure(scens[0])
    shapes = [l.shape for l in jax.tree_util.tree_leaves(scens[0])]
    for s, scen in zip(scenarios, scens[1:]):
        st = jax.tree_util.tree_structure(scen)
        sh = [l.shape for l in jax.tree_util.tree_leaves(scen)]
        if st != ref or sh != shapes:
            raise ValueError(
                f"stack_scenarios: scenario {getattr(s, 'name', s)!r} does "
                f"not realize to the shared canonical signature {pad} — "
                "widen the pad, e.g. canonical_pad(cluster, "
                "compose_depth=3) for 3-way compose() products "
                "(see registry_limits)")
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *scens)
    return stacked, np.asarray(caps, np.float64)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


_PAD_LOGIT = -1e30  # effectively -inf popularity: pad chunks are never drawn
#                     (finite so categorical's gumbel arithmetic stays NaN-free)


def _pad_placement(chunk_logits, chunk_locals, epoch_logits,
                   cluster: "Cluster", n_chunks: int, n_epochs: int):
    """Canonicalize the placement axis to ``n_chunks`` catalog rows and
    ``n_epochs`` churn-epoch popularity rows.

    Uniform scenarios get a dummy catalog (never drawn: placement_on = 0);
    skewed ones are padded with _PAD_LOGIT rows.  Pad triples are the first
    n_replicas server ids — valid, but selected with probability ~0.
    epoch_logits is always emitted canonically: single-epoch placements
    mirror the global law in row 0 (identical values, so canonical draws
    are bit-identical to the pre-epoch behavior); unused epoch rows are
    all-_PAD_LOGIT and never indexed by placement_epoch."""
    n_rep = cluster.n_replicas
    dummy_row = np.arange(n_rep, dtype=np.int32)[None, :]
    if chunk_logits is None:
        logits = np.full(n_chunks, _PAD_LOGIT, np.float32)
        locals_ = np.repeat(dummy_row, n_chunks, axis=0)
        on = 0.0
    else:
        logits = np.asarray(chunk_logits, np.float32)
        locals_ = np.asarray(chunk_locals, np.int32)
        C = logits.shape[0]
        assert C <= n_chunks, (C, n_chunks)
        logits = np.pad(logits, (0, n_chunks - C),
                        constant_values=_PAD_LOGIT)
        locals_ = np.concatenate(
            [locals_, np.repeat(dummy_row, n_chunks - C, axis=0)], axis=0)
        on = 1.0
    if epoch_logits is None:
        elog = np.full((n_epochs, n_chunks), _PAD_LOGIT, np.float32)
        elog[0] = logits
    else:
        elog = np.asarray(epoch_logits, np.float32)
        E, C = elog.shape
        assert E <= n_epochs and C <= n_chunks, (elog.shape, n_epochs,
                                                 n_chunks)
        elog = np.pad(elog, ((0, n_epochs - E), (0, n_chunks - C)),
                      constant_values=_PAD_LOGIT)
    return (jnp.asarray(logits), jnp.asarray(locals_), jnp.float32(on),
            jnp.asarray(elog))


def realize(scenario: Scenario, cluster: "Cluster", rates: "Rates",
            T: int, pad: Optional[ScenarioPad] = None
            ) -> tuple[ScenarioData, float]:
    """Build the ScenarioData arrays + the capacity-region edge (tasks/slot
    at load = 1) for this scenario.  Deterministic in ``scenario.seed``.

    ``pad`` canonicalizes the pytree: window arrays are padded to
    pad.n_windows (inactive rows), the placement catalog to pad.n_chunks,
    and ``placement_on`` selects the placement law by data — so every
    scenario realized with the same pad shares one jit signature (the
    one-compile sweep; see canonical_pad / tests/test_scenarios.py's
    recompile-count guard).  pad=None reproduces the unpadded pytrees
    exactly."""
    rng = np.random.default_rng(scenario.seed)
    base, wstart, wend, wmult = _fleet_arrays(scenario.fleet, cluster, T, rng)
    lam_shape = traffic_shape(scenario.traffic, T, rng)
    chunk_logits, chunk_locals, epoch_logits = _placement_arrays(
        scenario.placement, cluster, rng)
    # slot -> churn-epoch map (trace-backed placements re-derive their
    # catalog per episode; everything else is single-epoch)
    placement_epoch = (
        jnp.asarray(np.asarray(scenario.placement.realize_epochs(T),
                               np.int32))
        if hasattr(scenario.placement, "realize_epochs") else None)
    # per-task size-multiplier law: lognormal normalized to mean exactly 1
    # (mu = -sigma^2/2), so lam_cap below needs no size correction; always
    # concrete scalars so every realization shares one pytree structure
    sigma = float(scenario.sizes.sigma)
    placement_on = None
    if pad is not None:
        E = wstart.shape[0]
        if E > pad.n_windows:
            raise ValueError(
                f"scenario {scenario.name!r} has {E} event windows but the "
                f"pad reserves only {pad.n_windows} (the default budget "
                f"covers {COMPOSE_DEPTH}-way compose() products).  Widen "
                f"it explicitly: canonical_pad(cluster, "
                f"compose_depth={max(2, -(-E // max(pad.n_windows // COMPOSE_DEPTH, 1)))}) "
                f"— or pad._replace(n_windows={E}) for a one-off")
        wstart = np.pad(wstart, (0, pad.n_windows - E))
        wend = np.pad(wend, (0, pad.n_windows - E))      # start == end: inert
        wmult = np.pad(wmult, ((0, pad.n_windows - E), (0, 0), (0, 0)),
                       constant_values=1.0)
        chunk_logits, chunk_locals, placement_on, epoch_logits = \
            _pad_placement(chunk_logits, chunk_locals, epoch_logits,
                           cluster, pad.n_chunks, pad.n_epochs)
        if placement_epoch is None:
            placement_epoch = jnp.zeros(T, jnp.int32)
    scen = ScenarioData(
        lam_shape=jnp.asarray(lam_shape),
        base_speed=jnp.asarray(base),
        win_start=jnp.asarray(wstart),
        win_end=jnp.asarray(wend),
        win_mult=jnp.asarray(wmult),
        chunk_logits=chunk_logits,
        chunk_locals=chunk_locals,
        placement_on=placement_on,
        size_mu=jnp.float32(-0.5 * sigma * sigma),
        size_sigma=jnp.float32(sigma),
        epoch_logits=epoch_logits,
        placement_epoch=placement_epoch,
    )
    # placement-aware capacity edge: uniform placement keeps the closed
    # form bit-for-bit; skewed catalogs get the fluid-LP optimum (local
    # import — capacity.py imports capacity_scale from this module)
    from .capacity import capacity_edge
    lam_cap = capacity_edge(scen, cluster, rates, T)
    return scen, lam_cap
