"""Correlated-failure window generators.

Real fleets do not fail one server at a time: a PDU trip or a bad rollout
takes out a whole pod at once, and a single straggling server backs up its
rack's top-of-rack switch so every *rack-local* transfer through it slows
down.  These generators author such patterns as plain ``WindowSpec`` tuples
— nothing downstream (realization, canonical padding, the one-compile
sweep) knows or cares that a window list came from a generator rather than
being written by hand.

Both are deterministic in their ``seed`` (host-side numpy rng; no jax
keys), and cluster-agnostic the same way hand-written windows are: they
speak in rack ids and rack-member indices, which ``build._window_mask``
resolves against the concrete cluster at realization time.
"""
from __future__ import annotations

import numpy as np

from .spec import WindowSpec


def _power_law_durations(rng: np.random.Generator, n: int, alpha: float,
                         dur_min: float, dur_max: float) -> np.ndarray:
    """n Pareto(alpha)-distributed durations (fractions of T), clipped.

    Inversion sampling: dur = dur_min * (1 - u)^(-1/alpha) — the standard
    heavy-tailed outage-length model (most blips are short, a few windows
    run long)."""
    u = rng.random(n)
    return np.minimum(dur_min * (1.0 - u) ** (-1.0 / alpha), dur_max)


def correlated_outages(*, n_events: int, n_racks: int, seed: int,
                       alpha: float = 1.2, dur_min: float = 0.02,
                       dur_max: float = 0.20,
                       t_range: tuple = (0.10, 0.90)) -> tuple:
    """Whole-pod failures with power-law durations.

    Each event drains one rack completely (``mult=0.0`` — the correlated
    analogue of ``rack_outage``): onset uniform in ``t_range``, duration
    Pareto(``alpha``) between ``dur_min`` and ``dur_max`` fractions of the
    run, rack uniform among the first ``n_racks`` racks (use the smallest
    rack count of the presets the scenario must run on).  Deterministic in
    ``seed``; events may overlap — overlapping windows on the same rack
    compose multiplicatively, and 0 * anything is still an outage.
    """
    rng = np.random.default_rng(seed)
    racks = rng.integers(0, n_racks, n_events)
    t0 = rng.uniform(t_range[0], t_range[1], n_events)
    dur = _power_law_durations(rng, n_events, alpha, dur_min, dur_max)
    return tuple(
        WindowSpec(t0=float(t0[e]), t1=float(min(t0[e] + dur[e], 1.0)),
                   mult=0.0, rack=int(racks[e]))
        for e in range(n_events))


def cascading_stragglers(*, n_events: int, n_racks: int, seed: int,
                         straggler_mult: float = 0.25,
                         beta_mult: float = 0.5,
                         dur_min: float = 0.10, dur_max: float = 0.25,
                         t_range: tuple = (0.15, 0.75)) -> tuple:
    """A slow server degrades its rack's beta tier via the shared ToR.

    Each event emits TWO windows over the same interval: the straggler
    itself (one rack member, whole-server ``straggler_mult`` — its disk or
    host NIC is sick, so every tier it serves slows), and the *cascade* —
    the rest of the story a whole-server model cannot tell: the straggler's
    retransmissions sit on the rack's shared ToR uplinks, so every server
    in that rack serves rack-local (beta) traffic at ``beta_mult`` while
    local and remote tiers are untouched (``mult=(1, beta_mult, 1)`` — a
    per-class window).  The straggler is addressed as a (rack, member)
    pair, resolved against the concrete cluster at realization.
    """
    rng = np.random.default_rng(seed)
    windows = []
    for _ in range(n_events):
        rack = int(rng.integers(0, n_racks))
        member = int(rng.integers(0, 1 << 16))    # mod rack_size at realize
        t0 = float(rng.uniform(t_range[0], t_range[1]))
        t1 = float(min(t0 + rng.uniform(dur_min, dur_max), 1.0))
        windows.append(WindowSpec(t0=t0, t1=t1, mult=straggler_mult,
                                  rack_member=(rack, member)))
        windows.append(WindowSpec(t0=t0, t1=t1,
                                  mult=(1.0, beta_mult, 1.0), rack=rack))
    return tuple(windows)
