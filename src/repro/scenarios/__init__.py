"""Scenario engine: heterogeneous fleets, non-stationary traffic, skewed data.

The paper's model (and the seed simulator) is symmetric along every axis the
paper's *title* is about: all M servers run at the same speed, arrivals are a
stationary Poisson stream, and each task's replica triple is uniform over
servers.  This package breaks each symmetry independently and composably,
so Balanced-Pandas(-Pod) and the JSQ family can be stress-tested where their
guarantees actually differ:

  fleet heterogeneity  (``FleetSpec``)
      Per-server speed multipliers (persistently slow racks / servers) plus
      time-indexed event windows — straggler onset & recovery, drains and
      outages (multiplier 0).  A server's effective service *rate* for
      locality class c at slot t is  rates[c] * speed_t[m]:  an [M, 3] rate
      matrix that varies over time.

  traffic shape        (``TrafficSpec``)
      Stationary Poisson, 2-state MMPP bursts, diurnal sinusoid, and
      flash-crowd steps.  Realized host-side as a length-T intensity trace
      normalized to mean 1, so a requested ``load`` keeps its meaning as a
      fraction of time-averaged capacity.

  data placement skew  (``PlacementSpec``)
      Zipf chunk popularity: tasks draw a chunk from a Zipf law and inherit
      that chunk's fixed replica triple, producing hot local-server triples
      instead of the seed's uniform ``sample_locals``.

Per-server rate model
---------------------
Service durations are still sampled once at service start, in *speed-1 work
units* at the class rate (geometric / log-normal exactly as before); a busy
server then completes ``speed_t[m]`` units of work per slot.  For a constant
speed s this reproduces rate scaling (mean duration 1/(s * rates[c]) slots)
while also doing the right thing mid-flight: a server that *becomes* a
straggler slows the task it is already serving — which is what a real
straggler does — and a drained server (speed 0) freezes, neither finishing
nor starting work.  The Balanced-Pandas workload metric divides each
sub-queue by the server's *own current* rate, W_m = sum_c Q[m,c] /
(speed_t[m] * rates[c]), so routing sees stragglers as long queues.

Capacity under heterogeneity: at the boundary every task is served locally
at its server's own speed, so the region edge generalizes from M * alpha to
alpha * sum_m speed_m, time-averaged over the run (``Scenario`` realization
computes this so ``load`` stays comparable across scenarios).  This edge
accounts for the *fleet* axis only: placement skew can shrink the true
stable region further (a hot chunk's triple saturates its three local
servers and the excess must be served rack-local/remote at beta/gamma), so
for Zipf scenarios ``load`` is a fraction of the placement-free bound and
high-load runs may be supercritical — the simulator's ``drift`` metric
flags that explicitly.  A placement-aware capacity LP is a ROADMAP item.

Specs are tiny frozen dataclasses (a registry of named instances lives in
``SCENARIOS``); ``realize()`` turns one into a ``ScenarioData`` pytree of
arrays that the jit'd simulator scans over — nothing in the hot loop
branches on Python state.
"""
from .spec import (
    SCENARIOS,
    FleetSpec,
    PlacementSpec,
    Scenario,
    TrafficSpec,
    WindowSpec,
    get_scenario,
    register,
    registry_limits,
    scenario_names,
)
from .build import (
    ScenarioData,
    ScenarioPad,
    arrival_counts,
    canonical_a_max,
    canonical_pad,
    capacity_scale,
    realize,
    sample_locals_scenario,
    speed_at,
    speed_trace,
    traffic_shape,
)

__all__ = [n for n in dir() if not n.startswith("_")]
