"""Scenario engine: composable axes of heterogeneity.

The paper's model (and the seed simulator) is symmetric along every axis
its *title* is about: all M servers run at the same speed, arrivals are a
stationary Poisson stream, and each task's replica triple is uniform over
servers.  This package breaks each symmetry as an independent **axis
spec**, and — because real incidents are products, not single axes (a slow
rack *during* a flash crowd *with* hot data) — makes the axes
**composable**:

  fleet heterogeneity  (``FleetSpec``)
      Persistent per-server speeds (slow racks, random slow cohorts) plus
      time-indexed event ``WindowSpec``s.  A window's multiplier is a
      scalar (whole-server straggler/outage: every tier slows together) or
      a per-locality-class triple — ``(1.0, 0.4, 0.25)`` scales only the
      rack-local (beta, ICI) and remote (gamma, DCN) tiers, expressing
      network congestion that leaves HBM-local service untouched.
      ``generators.py`` authors correlated patterns as plain window
      tuples: ``correlated_outages`` (whole-pod failures, power-law
      durations) and ``cascading_stragglers`` (a sick server drags its
      rack's beta tier down through the shared ToR — a per-class window).

  traffic shape        (``TrafficSpec``)
      Stationary Poisson, 2-state MMPP bursts, diurnal sinusoid, and
      flash-crowd steps, realized host-side as a length-T mean-1 intensity
      trace.  Composition multiplies mean-1 shapes and renormalizes
      (``TrafficProduct``), so ``load`` keeps its meaning as a fraction of
      time-averaged capacity.

  data placement skew  (``PlacementSpec``)
      Zipf chunk popularity over a fixed replica catalog.  Composition is
      rightmost-non-uniform-wins — catalogs never union.

The compose() algebra
---------------------
``compose(*scenarios, name=...)`` folds scenarios axis-by-axis (each axis
spec knows how to ``merge`` with its own kind): fleet windows union and
persistent speeds multiply; traffic shapes multiply; placement picks the
rightmost skewed law.  The registry's product scenarios (``hetero_storm``,
``outage_storm``, ``cascade_flash``) are themselves compositions of the
axis entries, and the benchmark sweep accepts ad-hoc products as
``--scenarios=slow_rack+flash_crowd``.  ``registry_limits`` reserves
canonical-padding headroom for pairwise compositions, so any
``compose(a, b)`` of registry scenarios realizes to the same canonical
pytree signature as the registry itself and rides the one-compile sweep.

Per-server, per-class rate model
--------------------------------
Realization turns windows into an ``[E, M, 3]`` multiplier stack;
``speed_at`` reduces it to the slot's ``[M, 3]`` speed matrix.  Service
durations are sampled once at service start, in *speed-1 work units* at
the class rate; a busy server then completes ``speed_t[m, c]`` units per
slot for its in-flight class-c task.  A server that *becomes* a straggler
slows the task it is already serving; a drained server (speed 0) freezes;
a server whose beta tier is down keeps serving local work.  The
Balanced-Pandas workload metric divides each sub-queue by the server's own
current rates, with zero-rate tiers carried as ``+inf`` inverse rates (the
kernels' contract): they contribute no workload and score ``+inf`` in
routing, so an empty drained server is never selected.

Capacity — the honest, placement-aware edge
-------------------------------------------
``realize`` returns ``(ScenarioData, lam_cap)``; every ``load`` knob in
the repo is a fraction of that edge.  For uniform placement the edge is
the fleet closed form ``alpha * sum_m local_speed_m``, time-averaged over
windows (only the class-0 column moves it) — kept BIT-FOR-BIT.  For
skewed catalogs (Zipf, adversarial, trace-backed epochs) ``lam_cap`` is
the optimum of the fluid LP in :mod:`repro.scenarios.capacity` over
per-(chunk, server, locality-class) flow rates: hot chunks saturate their
few replica holders first and the overflow is priced at the slower
beta/gamma tiers, integrated over speed segments and placement-churn
epochs.  That LP edge is strictly below the closed form whenever the
local tier binds (zipf_hotspot ~0.86x at M=24, adversarial ~0.46x), so
``load < 1`` now means genuinely subcritical for every scenario —
historical benchmark rows recorded under the old placement-free bound
drove skewed scenarios harder than their nominal load.  The LP is
host-side scipy/HiGHS (memoized; loud closed-form fallback without
scipy) and never touches the jit'd path, so the one-compile sweep
invariant is untouched.  Runs that still need convergence help use the
drift-aware auto-extend warmup loop (``telemetry.auto_extend_warmup`` /
``core.simulate_auto_warmup``): one full-T run, then the measurement
boundary advances over exact telemetry window sums until the windowed
drift of the tail falls below ``WarmupPolicy.threshold`` (1.05) or the
cap fires — unmeasurable (NaN) drift is reported loudly as NOT
converged, never as clean.

Specs are tiny frozen dataclasses (a registry of named instances lives in
``SCENARIOS``); ``realize()`` turns one into a ``ScenarioData`` pytree of
arrays that the jit'd simulator scans over — nothing in the hot loop
branches on Python state.
"""
from .spec import (
    COMPOSE_DEPTH,
    SCENARIOS,
    FleetSpec,
    PlacementSpec,
    Scenario,
    SizeSpec,
    TrafficProduct,
    TrafficSpec,
    WindowSpec,
    compose,
    get_scenario,
    register,
    registry_limits,
    scenario_names,
)
from .generators import cascading_stragglers, correlated_outages
from .capacity import capacity_edge, fluid_edge, uniform_edge
from .build import (
    ScenarioData,
    ScenarioPad,
    arrival_counts,
    canonical_a_max,
    canonical_pad,
    capacity_scale,
    placement_epoch_at,
    realize,
    sample_locals_scenario,
    speed_at,
    speed_trace,
    stack_scenarios,
    traffic_shape,
)

# trace-backed registry entries (production_day) register on import; the
# trace package only pulls spec/build (already initialized above) at import
# time — its replay layer, which needs the simulator, loads lazily
from .. import trace as _trace  # noqa: E402,F401

__all__ = [n for n in dir() if not n.startswith("_")]
