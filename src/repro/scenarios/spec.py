"""Scenario specs + the named-scenario registry (see package docstring)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Union


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """A time window during which a set of servers changes speed.

    t0/t1 are fractions of the run length T (scenarios are T-agnostic);
    the affected set is a rack, an [lo, hi) server-id interval, or every
    f-th server — whichever selector is not None.  mult multiplies the
    servers' base speed inside the window (0.0 == outage/drain)."""

    t0: float
    t1: float
    mult: float
    rack: Optional[int] = None
    servers: Optional[tuple] = None        # (lo, hi) server-id interval
    every: Optional[int] = None            # servers m with m % every == phase
    phase: int = 0


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Persistent per-server speeds + transient event windows."""

    rack_speeds: tuple = ()                # per-rack multiplier ((): all 1.0)
    slow_frac: float = 0.0                 # fraction of servers slowed ...
    slow_mult: float = 1.0                 # ... persistently, by this factor
    windows: tuple = ()                    # of WindowSpec

    @property
    def uniform(self) -> bool:
        return (not self.rack_speeds and not self.windows
                and (self.slow_frac == 0.0 or self.slow_mult == 1.0))


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Arrival-intensity shape, normalized to mean 1 at realization."""

    kind: str = "stationary"               # |diurnal|flash|mmpp
    # diurnal: lam(t) = 1 + amp * sin(2 pi * cycles * t / T)
    amp: float = 0.35
    cycles: float = 3.0
    # flash crowd: intensity steps to `peak` x base inside [t0, t1) x T
    t0: float = 0.5
    t1: float = 0.6
    peak: float = 2.5
    # mmpp: 2-state chain, burst state `burst` x the quiet intensity
    burst: float = 3.0
    p_enter: float = 0.003                 # quiet -> burst per slot
    p_exit: float = 0.01                   # burst -> quiet per slot


@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """Where chunk replicas live; 'zipf' makes some triples hot."""

    kind: str = "uniform"                  # |zipf
    zipf_s: float = 1.2                    # popularity exponent
    chunks_per_server: int = 4             # catalog size C = this * M


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    fleet: FleetSpec = FleetSpec()
    traffic: TrafficSpec = TrafficSpec(kind="stationary")
    placement: PlacementSpec = PlacementSpec()
    seed: int = 0                          # host-side realization seed
    description: str = ""


SCENARIOS: dict[str, Scenario] = {}


def register(s: Scenario) -> Scenario:
    if s.name in SCENARIOS:
        raise ValueError(f"scenario {s.name!r} already registered")
    SCENARIOS[s.name] = s
    return s


def scenario_names() -> tuple[str, ...]:
    return tuple(SCENARIOS)


def registry_limits(scenarios=None) -> tuple[int, int]:
    """Registry-wide shape maxima for canonical pytree padding.

    Returns (max event-window count, max chunks_per_server among non-uniform
    placements; 0 when every scenario places uniformly).  build.canonical_pad
    turns these into concrete array shapes so every scenario realizes to the
    same pytree signature and the jit'd simulator compiles once for the
    whole sweep.
    """
    specs = tuple(scenarios) if scenarios is not None else tuple(
        SCENARIOS.values())
    n_windows = max((len(s.fleet.windows) for s in specs), default=0)
    chunks = max((s.placement.chunks_per_server for s in specs
                  if s.placement.kind != "uniform"), default=0)
    return n_windows, chunks


def get_scenario(s: Union[str, Scenario, None]) -> Scenario:
    if s is None:
        return SCENARIOS["uniform"]
    if isinstance(s, Scenario):
        return s
    try:
        return SCENARIOS[s]
    except KeyError:
        raise KeyError(f"unknown scenario {s!r}; "
                       f"registered: {sorted(SCENARIOS)}") from None


# ---------------------------------------------------------------------------
# The named registry.  `uniform` reproduces the seed simulator exactly; each
# other scenario breaks one axis (or, for the storm, all three).
# ---------------------------------------------------------------------------

register(Scenario(
    "uniform",
    description="the paper's symmetric baseline: equal speeds, stationary "
                "Poisson, uniform replica placement"))

register(Scenario(
    "slow_rack",
    fleet=FleetSpec(rack_speeds=(0.5,)),   # rack 0 at half speed, rest 1.0
    description="one rack persistently at half speed (heterogeneous-server "
                "baseline; GB-PANDAS's motivating asymmetry)"))

register(Scenario(
    "straggler_wave",
    fleet=FleetSpec(windows=(
        WindowSpec(t0=0.20, t1=0.40, mult=0.25, every=10, phase=0),
        WindowSpec(t0=0.35, t1=0.55, mult=0.25, every=10, phase=3),
        WindowSpec(t0=0.50, t1=0.70, mult=0.25, every=10, phase=6),
        WindowSpec(t0=0.65, t1=0.85, mult=0.25, every=10, phase=9),
    )),
    description="overlapping straggler cohorts: every 10th server drops to "
                "quarter speed, onset staggered, each recovering"))

register(Scenario(
    "rack_outage",
    fleet=FleetSpec(windows=(
        WindowSpec(t0=0.45, t1=0.55, mult=0.0, rack=0),)),
    description="rack 0 drains completely for 10% of the run, then "
                "recovers (failure window as a zero rate mask)"))

register(Scenario(
    "diurnal_burst",
    traffic=TrafficSpec(kind="diurnal", amp=0.35, cycles=3.0),
    description="sinusoidal arrival intensity, +/-35% around the mean over "
                "3 cycles (diurnal load)"))

register(Scenario(
    "flash_crowd",
    traffic=TrafficSpec(kind="flash", t0=0.5, t1=0.6, peak=2.5),
    description="stationary arrivals with a 2.5x step for 10% of the run "
                "(flash crowd / retry storm)"))

register(Scenario(
    "mmpp_bursty",
    traffic=TrafficSpec(kind="mmpp", burst=3.0, p_enter=0.003, p_exit=0.01),
    description="Markov-modulated Poisson: random bursts at 3x the quiet "
                "intensity (bursty production traffic)"))

register(Scenario(
    "zipf_hotspot",
    placement=PlacementSpec(kind="zipf", zipf_s=1.2),
    description="Zipf(1.2) chunk popularity: a few replica triples receive "
                "most of the tasks (hot data)"))

register(Scenario(
    "hetero_storm",
    fleet=FleetSpec(rack_speeds=(0.5,), windows=(
        WindowSpec(t0=0.30, t1=0.50, mult=0.25, every=10, phase=0),)),
    traffic=TrafficSpec(kind="diurnal", amp=0.30, cycles=3.0),
    placement=PlacementSpec(kind="zipf", zipf_s=1.1),
    description="all three axes at once: slow rack + straggler cohort + "
                "diurnal traffic + Zipf placement"))
