"""Scenario axis specs, the ``compose()`` algebra, and the named registry.

A :class:`Scenario` is a product of three independent *axis specs* —
:class:`FleetSpec` (who is slow / down, and when), :class:`TrafficSpec`
(how arrivals breathe), :class:`PlacementSpec` (where the data lives).
Each axis is **mergeable**: ``axis.merge(other)`` combines two specs of the
same axis, and :func:`compose` folds whole scenarios together axis-by-axis:

  fleet      event windows union; persistent rack speeds multiply
             elementwise; slow cohorts accumulate (each drawn
             independently at realization).
  traffic    product of the mean-1 intensity shapes, renormalized to
             mean 1 (a diurnal tide modulating a flash crowd).
  placement  the rightmost non-uniform placement wins (compose does not
             union chunk catalogs).

So ``compose("slow_rack", "flash_crowd")`` is a first-class experiment and
the registry no longer needs a hand-written product scenario per
combination — the shipped products (``hetero_storm``, ``outage_storm``,
``cascade_flash``) are themselves registered compositions.

Window multipliers are per locality class: ``WindowSpec.mult`` is either a
scalar (whole-server slowdown/outage — every tier scales together) or a
3-tuple ``(local, rack, remote)`` scaling each service tier independently,
which expresses network-tier degradation (ICI/DCN congestion slows beta and
gamma service while HBM-local alpha service is untouched) and shared-ToR
cascades.  Generators for correlated failure patterns (whole-pod outages
with power-law durations, cascading stragglers) live in ``generators.py``
and emit plain ``WindowSpec`` tuples, so canonical padding and the
one-compile sweep are oblivious to how a window list was authored.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import operator
from typing import Optional, Union


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """A time window during which a set of servers changes speed.

    t0/t1 are fractions of the run length T (scenarios are T-agnostic);
    the affected set is a rack, an [lo, hi) server-id interval, every
    f-th server, or a single rack member — whichever selector is not
    None.  ``mult`` multiplies the servers' base speed inside the window
    (0.0 == outage/drain): a scalar applies to all three locality classes
    (whole-server event), a 3-tuple ``(local, rack, remote)`` scales each
    service tier independently (network-tier degradation)."""

    t0: float
    t1: float
    mult: Union[float, tuple]
    rack: Optional[int] = None
    servers: Optional[tuple] = None        # (lo, hi) server-id interval
    every: Optional[int] = None            # servers m with m % every == phase
    phase: int = 0
    rack_member: Optional[tuple] = None    # (rack, i): server rack*R + i % R

    @property
    def class_mult(self) -> tuple:
        """The per-class multiplier triple (scalars broadcast)."""
        if isinstance(self.mult, (int, float)):
            return (float(self.mult),) * 3
        m = tuple(float(x) for x in self.mult)
        if len(m) != 3:
            raise ValueError(f"per-class mult needs 3 entries, got {self.mult}")
        return m


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Persistent per-server speeds + transient event windows.

    ``slow_frac``/``slow_mult`` name one random slow cohort (kept as the
    authoring shorthand); ``slow`` carries further ``(frac, mult)`` cohorts
    accumulated by :meth:`merge`.  ``cohorts()`` is the flattened view the
    realizer draws from."""

    rack_speeds: tuple = ()                # per-rack multiplier ((): all 1.0)
    slow_frac: float = 0.0                 # fraction of servers slowed ...
    slow_mult: float = 1.0                 # ... persistently, by this factor
    windows: tuple = ()                    # of WindowSpec
    slow: tuple = ()                       # extra (frac, mult) cohorts

    def cohorts(self) -> tuple:
        """All (fraction, multiplier) slow-cohort pairs, head field first."""
        head = (((self.slow_frac, self.slow_mult),)
                if self.slow_frac > 0.0 and self.slow_mult != 1.0 else ())
        return head + tuple(self.slow)

    @property
    def uniform(self) -> bool:
        """True when the fleet is the paper's homogeneous baseline."""
        return (not self.rack_speeds and not self.windows
                and not self.cohorts())

    def merge(self, other: "FleetSpec") -> "FleetSpec":
        """Union windows, multiply persistent speeds, accumulate cohorts."""
        n = max(len(self.rack_speeds), len(other.rack_speeds))
        a = self.rack_speeds + (1.0,) * (n - len(self.rack_speeds))
        b = other.rack_speeds + (1.0,) * (n - len(other.rack_speeds))
        return FleetSpec(rack_speeds=tuple(x * y for x, y in zip(a, b)),
                         windows=self.windows + other.windows,
                         slow=self.cohorts() + other.cohorts())


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Arrival-intensity shape, normalized to mean 1 at realization."""

    kind: str = "stationary"               # |diurnal|flash|mmpp
    # diurnal: lam(t) = 1 + amp * sin(2 pi * cycles * t / T)
    amp: float = 0.35
    cycles: float = 3.0
    # flash crowd: intensity steps to `peak` x base inside [t0, t1) x T
    t0: float = 0.5
    t1: float = 0.6
    peak: float = 2.5
    # mmpp: 2-state chain, burst state `burst` x the quiet intensity
    burst: float = 3.0
    p_enter: float = 0.003                 # quiet -> burst per slot
    p_exit: float = 0.01                   # burst -> quiet per slot

    @property
    def parts(self) -> tuple:
        """Non-trivial factors of this shape (stationary is the identity)."""
        return () if self.kind == "stationary" else (self,)

    def merge(self, other) -> "Traffic":
        """Compose with another traffic shape (pointwise product)."""
        return _traffic_from_parts(self.parts + other.parts)


@dataclasses.dataclass(frozen=True)
class TrafficProduct:
    """Product of several mean-1 intensity shapes, renormalized to mean 1.

    Produced by composing scenarios with non-trivial traffic on both sides;
    realized by ``build.traffic_shape`` (factors multiply pointwise, then
    one final mean-1 normalization).  Deterministic factors (diurnal /
    flash) compose order-invariantly; stochastic factors (mmpp) consume
    host-rng draws in factor order."""

    factors: tuple                         # of TrafficSpec, each non-trivial

    @property
    def parts(self) -> tuple:
        """The non-trivial factors (already each non-stationary)."""
        return tuple(self.factors)

    def merge(self, other) -> "Traffic":
        """Compose with another traffic shape (factor union)."""
        return _traffic_from_parts(self.parts + other.parts)


Traffic = Union[TrafficSpec, TrafficProduct]


def _traffic_from_parts(parts: tuple) -> Traffic:
    if not parts:
        return TrafficSpec(kind="stationary")
    if len(parts) == 1:
        return parts[0]
    return TrafficProduct(tuple(parts))


@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """Where chunk replicas live; 'zipf' makes some triples hot.

    ``hot_rack`` pins the replica triples of the most popular catalog rows
    (the top ``hot_frac`` by Zipf rank) entirely inside one rack — the
    adversarial "all hot data on one rack" placement, where locality-blind
    routing must funnel most of the load through K-th of the fleet."""

    kind: str = "uniform"                  # |zipf
    zipf_s: float = 1.2                    # popularity exponent
    chunks_per_server: int = 4             # catalog size C = this * M
    hot_rack: Optional[int] = None         # rack holding all hot replicas
    hot_frac: float = 0.25                 # top fraction of rows pinned

    def merge(self, other: "PlacementSpec") -> "PlacementSpec":
        """Rightmost non-uniform placement wins (catalogs do not union)."""
        return other if other.kind != "uniform" else self


@dataclasses.dataclass(frozen=True)
class SizeSpec:
    """Per-task service-size multiplier law: lognormal, normalized to mean 1.

    ``sigma`` is the log-space standard deviation; the realizer pairs it
    with ``mu = -sigma^2 / 2`` so the multiplier's mean is exactly 1 and
    the capacity-region edge (lam_cap) is size-law invariant.  sigma = 0
    is the exact identity — the simulator's sampled durations are
    untouched bit-for-bit.  The trace->scenario compiler fits sigma from
    observed task sizes; merge composes independent lognormal factors
    (variances add in log space)."""

    sigma: float = 0.0

    @property
    def trivial(self) -> bool:
        """True for unit-size tasks (no size randomness)."""
        return self.sigma == 0.0

    def merge(self, other: "SizeSpec") -> "SizeSpec":
        """Compose lognormal spreads (variances add in log space)."""
        return SizeSpec(sigma=math.sqrt(self.sigma ** 2 + other.sigma ** 2))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named bundle of one value per axis (fleet / traffic / placement /
    sizes) — declarative; ``build.realize`` turns it into arrays."""
    name: str
    fleet: FleetSpec = FleetSpec()
    traffic: Traffic = TrafficSpec(kind="stationary")
    placement: PlacementSpec = PlacementSpec()
    sizes: SizeSpec = SizeSpec()
    seed: int = 0                          # host-side realization seed
    description: str = ""


SCENARIOS: dict[str, Scenario] = {}


def register(s: Scenario) -> Scenario:
    """Add a scenario to the global registry (name must be new)."""
    if s.name in SCENARIOS:
        raise ValueError(f"scenario {s.name!r} already registered")
    SCENARIOS[s.name] = s
    return s


def scenario_names() -> tuple[str, ...]:
    """Registered scenario names, in registration order."""
    return tuple(SCENARIOS)


def compose(*scenarios, name: Optional[str] = None,
            seed: Optional[int] = None,
            description: Optional[str] = None) -> Scenario:
    """Fold scenarios into one, merging each axis (see module docstring).

    Accepts registered names or Scenario objects.  Fleet windows union and
    persistent speeds multiply (order-invariant); traffic shapes multiply
    (order-invariant for deterministic shapes); placement is rightmost-
    non-uniform-wins (order matters only when several sides are skewed).
    ``seed`` defaults to the XOR of the parts' seeds — so composing with a
    seed-0 axis scenario preserves the other side's realization draws —
    and ``name`` to the parts' names joined with ``+`` (the spelling the
    benchmark ``--scenarios=`` filter accepts for ad-hoc compositions).

    Canonical-padding note: ``registry_limits`` reserves window slots for
    compositions of up to two registry scenarios (``COMPOSE_DEPTH``), so
    any pairwise ``compose`` realizes to the registry's canonical pytree
    signature.  A 3+-way product of window-carrying scenarios can overflow
    that budget; ``build.realize`` rejects it with a ValueError naming the
    fix — realize with ``build.canonical_pad(cluster, compose_depth=3)``
    (or more) to widen the shared signature for the whole sweep.
    """
    if not scenarios:
        raise ValueError("compose() needs at least one scenario")
    specs = [get_scenario(s) for s in scenarios]
    merged = lambda axis: functools.reduce(
        lambda a, b: a.merge(b), (getattr(s, axis) for s in specs))
    return Scenario(
        name=name or "+".join(s.name for s in specs),
        fleet=merged("fleet"),
        traffic=merged("traffic"),
        placement=merged("placement"),
        sizes=merged("sizes"),
        seed=seed if seed is not None
        else functools.reduce(operator.xor, (s.seed for s in specs)),
        description=description or (
            "composition: " + " x ".join(s.name for s in specs)),
    )


COMPOSE_DEPTH = 2   # pairwise compose() stays on the canonical signature


def registry_limits(scenarios=None,
                    compose_depth: Optional[int] = None
                    ) -> tuple[int, int, int]:
    """Registry-wide shape maxima for canonical pytree padding.

    Returns (max event-window count, max chunks_per_server among non-uniform
    placements — 0 when every scenario places uniformly — and max placement
    churn-epoch count).  build.canonical_pad turns these into concrete array
    shapes so every scenario realizes to the same pytree signature and the
    jit'd simulator compiles once for the whole sweep.

    The window budget is ``compose_depth`` (default ``COMPOSE_DEPTH`` = 2)
    x the largest single count, so a ``compose()`` of up to that many
    registry scenarios — whose windows union — still fits the canonical
    shapes (pads are inert rows; the cost is a few extra [M, 3] multiplier
    rows per scenario).  A 3+-way product of window-carrying scenarios can
    overflow the default budget; pass ``compose_depth=3`` (or more) here /
    to ``build.canonical_pad`` to widen it — ``build.realize`` and
    ``build.stack_scenarios`` name exactly that fix when they reject an
    overflowing composition.  Chunk catalogs and churn epochs need no such
    headroom: placement merge is rightmost-wins, never a union.  Epoch
    counts come from the duck-typed ``n_epochs`` attribute trace-backed
    placements carry (synthetic placements are single-epoch).
    """
    specs = tuple(get_scenario(s) for s in scenarios) \
        if scenarios is not None else tuple(SCENARIOS.values())
    depth = COMPOSE_DEPTH if compose_depth is None else int(compose_depth)
    if depth < 1:
        raise ValueError(f"compose_depth must be >= 1, got {depth}")
    n_windows = depth * max(
        (len(s.fleet.windows) for s in specs), default=0)
    chunks = max((s.placement.chunks_per_server for s in specs
                  if s.placement.kind != "uniform"), default=0)
    epochs = max((getattr(s.placement, "n_epochs", 1) for s in specs),
                 default=1)
    return n_windows, chunks, epochs


def get_scenario(s: Union[str, Scenario, None]) -> Scenario:
    """Resolve a name / Scenario / None (-> uniform baseline) to a Scenario."""
    if s is None:
        return SCENARIOS["uniform"]
    if isinstance(s, Scenario):
        return s
    try:
        return SCENARIOS[s]
    except KeyError:
        raise KeyError(f"unknown scenario {s!r}; "
                       f"registered: {sorted(SCENARIOS)}") from None


# ---------------------------------------------------------------------------
# The named registry.  `uniform` reproduces the seed simulator exactly; each
# base scenario breaks ONE axis; the product scenarios at the bottom are
# compose()d from the axis entries instead of re-spelling them.
# ---------------------------------------------------------------------------

register(Scenario(
    "uniform",
    description="the paper's symmetric baseline: equal speeds, stationary "
                "Poisson, uniform replica placement"))

register(Scenario(
    "slow_rack",
    fleet=FleetSpec(rack_speeds=(0.5,)),   # rack 0 at half speed, rest 1.0
    description="one rack persistently at half speed (heterogeneous-server "
                "baseline; GB-PANDAS's motivating asymmetry)"))

register(Scenario(
    "straggler_wave",
    fleet=FleetSpec(windows=(
        WindowSpec(t0=0.20, t1=0.40, mult=0.25, every=10, phase=0),
        WindowSpec(t0=0.35, t1=0.55, mult=0.25, every=10, phase=3),
        WindowSpec(t0=0.50, t1=0.70, mult=0.25, every=10, phase=6),
        WindowSpec(t0=0.65, t1=0.85, mult=0.25, every=10, phase=9),
    )),
    description="overlapping straggler cohorts: every 10th server drops to "
                "quarter speed, onset staggered, each recovering"))

register(Scenario(
    "rack_outage",
    fleet=FleetSpec(windows=(
        WindowSpec(t0=0.45, t1=0.55, mult=0.0, rack=0),)),
    description="rack 0 drains completely for 10% of the run, then "
                "recovers (failure window as a zero rate mask)"))

register(Scenario(
    "diurnal_burst",
    traffic=TrafficSpec(kind="diurnal", amp=0.35, cycles=3.0),
    description="sinusoidal arrival intensity, +/-35% around the mean over "
                "3 cycles (diurnal load)"))

register(Scenario(
    "flash_crowd",
    traffic=TrafficSpec(kind="flash", t0=0.5, t1=0.6, peak=2.5),
    description="stationary arrivals with a 2.5x step for 10% of the run "
                "(flash crowd / retry storm)"))

register(Scenario(
    "mmpp_bursty",
    traffic=TrafficSpec(kind="mmpp", burst=3.0, p_enter=0.003, p_exit=0.01),
    description="Markov-modulated Poisson: random bursts at 3x the quiet "
                "intensity (bursty production traffic)"))

register(Scenario(
    "zipf_hotspot",
    placement=PlacementSpec(kind="zipf", zipf_s=1.2),
    description="Zipf(1.2) chunk popularity: a few replica triples receive "
                "most of the tasks (hot data)"))

register(Scenario(
    "adversarial_placement",
    placement=PlacementSpec(kind="zipf", zipf_s=1.2, hot_rack=0,
                            hot_frac=0.25),
    description="adversarial placement: every hot chunk's replica triple "
                "lives entirely on rack 0, so locality-aware routing "
                "funnels most of the load through one rack while the rest "
                "of the fleet only sees remote (gamma) service"))

# -- per-class (network-tier) degradation and correlated failures -----------
# generators.py is imported late so its `from .spec import WindowSpec` sees
# the classes above while this module is still initializing (no cycle).
from .generators import cascading_stragglers, correlated_outages  # noqa: E402

register(Scenario(
    "network_degraded",
    fleet=FleetSpec(windows=(
        WindowSpec(t0=0.30, t1=0.70, mult=(1.0, 0.4, 0.25), every=1),)),
    description="ICI/DCN congestion: rack-local (beta) and remote (gamma) "
                "tiers drop to 40%/25% fleet-wide for the middle of the "
                "run; local (alpha) service is untouched"))

register(Scenario(
    "pod_flap",
    fleet=FleetSpec(windows=correlated_outages(n_events=3, n_racks=4,
                                               seed=101)),
    description="correlated whole-pod failures: rack-wide outages with "
                "power-law durations (host-seeded generator)"))

register(Scenario(
    "tor_cascade",
    fleet=FleetSpec(windows=cascading_stragglers(n_events=2, n_racks=4,
                                                 seed=202)),
    description="cascading stragglers: a slow server drags its whole "
                "rack's beta tier down through the shared ToR"))

# -- product scenarios: compositions of the axis entries above --------------

register(compose(
    "slow_rack",
    Scenario("storm_wave", fleet=FleetSpec(windows=(
        WindowSpec(t0=0.30, t1=0.50, mult=0.25, every=10, phase=0),))),
    Scenario("storm_tide", traffic=TrafficSpec(kind="diurnal", amp=0.30,
                                               cycles=3.0)),
    Scenario("storm_data", placement=PlacementSpec(kind="zipf", zipf_s=1.1)),
    name="hetero_storm",
    description="all three axes at once: slow rack + straggler cohort + "
                "diurnal traffic + Zipf placement"))

register(compose(
    "pod_flap", "mmpp_bursty",
    name="outage_storm",
    description="correlated pod failures during bursty (MMPP) traffic"))

register(compose(
    "tor_cascade", "flash_crowd", "zipf_hotspot",
    name="cascade_flash",
    description="shared-ToR straggler cascade under a flash crowd on hot "
                "(Zipf) data"))
