from .pipeline import PipelineConfig, SyntheticLM
