"""Deterministic synthetic LM data pipeline with checkpointable state.

Production shape without external deps: the stream is a pure function of
(seed, step, host shard), so (a) every host reads only its shard, (b) the
pipeline cursor is one integer — it checkpoints/restores exactly, and (c) a
resumed run is bitwise-identical to an uninterrupted one (tested).

The token distribution is a mixture of Zipf-like unigrams and a short
Markov chain so tiny models have real structure to fit (train-loss-decreases
tests and the overfit example rely on this).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Iterator yielding {"tokens": [B_host, S], "labels": [B_host, S]}."""

    def __init__(self, cfg: PipelineConfig, step: int = 0):
        if cfg.global_batch % cfg.n_hosts != 0:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.step = step
        v = cfg.vocab
        # fixed "language": Zipf unigram + deterministic bigram successor
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(0, v, size=v, dtype=np.int64)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "restoring a different stream"
        self.step = int(state["step"])

    def next_batch(self) -> dict:
        cfg = self.cfg
        b_host = cfg.global_batch // cfg.n_hosts
        # per-(step, host) independent stream — reproducible at any cursor
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, self.step, cfg.host_id]))
        first = rng.choice(cfg.vocab, size=(b_host, 1), p=self._unigram)
        toks = [first[:, 0]]
        noise = rng.random((b_host, cfg.seq_len))
        fresh = rng.choice(cfg.vocab, size=(b_host, cfg.seq_len),
                           p=self._unigram)
        for t in range(1, cfg.seq_len + 1):
            prev = toks[-1]
            nxt = np.where(noise[:, t - 1] < 0.75, self._succ[prev],
                           fresh[:, t - 1])
            toks.append(nxt)
        seq = np.stack(toks, axis=1).astype(np.int32)   # [B, S+1]
        self.step += 1
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def __iter__(self):
        return self

    def __next__(self):
        return self.next_batch()
