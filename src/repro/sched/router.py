"""PodRouter — the paper's Balanced-Pandas-Pod as a production request
router, backed by the Pallas kernels.

The router keeps the paper's per-replica 3-sub-queue bookkeeping (Q[m, c]
counts of requests queued at replica m in locality class c) and its
workload metric W_m = Q^l/alpha + Q^k/beta + Q^r/gamma, and routes each
request batch with ONE fused kernels.route_commit launch — score, route,
and queue-commit with in-kernel sequential conflict resolution, so request
b+1 in a batch scores against workloads that already include request b's
commit (no snapshot herding under bursts):

  policy="pod"  -> route_commit pod variant  (O(d) probes per request —
                   paper §IV-C candidate lists)
  policy="full" -> route_commit full variant (O(M) baseline Balanced-Pandas)

The kernel also updates Q and W in the same launch (the old three-call
pod_route/weighted_argmin + queue_update sequence is gone), and breaks
exact score ties by locality class then index — no epsilon lifts.  The
complexity counter the benchmarks report (probes per decision) is exactly
the candidate-set width handed to the kernel.

Heterogeneous fleets: pass ``rate_matrix`` ([M, 3] per-replica per-class
service rates, e.g. from repro.core.rate_matrix with scenario speeds).  The
workload metric and routing scores then divide by each replica's *own*
rates — and the SAME Pallas kernels serve both forms: their inverse-rate
operand is [3] or [M, 3] (the per-candidate rate gather rides the kernels'
existing one-hot matmul), so the router never leaves the MXU path.  A
zero-rate replica (drained / outage) carries a ``+inf`` inverse rate; the
kernels mask it to a ``+inf`` score after the multiply, so it is never
selected while any live candidate exists.  With identical rate-matrix rows
the heterogeneous path is bit-identical to the homogeneous one
(tests/test_scenarios.py asserts this).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cluster import LOCAL, RACK, REMOTE, Rates
from ..core.policies import PodSpec
from ..kernels import route_commit
from .locality import FleetTopology


@dataclasses.dataclass
class RouterStats:
    decisions: int = 0
    probes: int = 0
    routed_by_class: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.routed_by_class is None:
            self.routed_by_class = np.zeros(3, np.int64)


class PodRouter:
    def __init__(self, fleet: FleetTopology, rates: Rates,
                 policy: str = "pod", pod: PodSpec = PodSpec(2, 6),
                 seed: int = 0,
                 rate_matrix: Optional[np.ndarray] = None):
        assert policy in ("pod", "full")
        self.fleet = fleet
        self.rates = rates
        self.policy = policy
        self.pod = pod
        self.M = fleet.n_replicas
        self.Q = jnp.zeros((self.M, 3), jnp.int32)
        self.W = jnp.zeros((self.M,), jnp.float32)
        self.inv_rates = 1.0 / rates.as_array()
        if rate_matrix is not None:
            rm = np.asarray(rate_matrix, np.float32)
            assert rm.shape == (self.M, 3), rm.shape
            # zero-rate (drained) replicas -> +inf inverse rate; the kernels
            # mask these to +inf scores (never 0 * inf = NaN).
            rmj = jnp.asarray(rm)
            self.inv_rate_m = jnp.where(rmj > 0, 1.0 / rmj, jnp.inf)
        else:
            self.inv_rate_m = None
        self.key = jax.random.PRNGKey(seed)
        self.stats = RouterStats()
        R = self.M // fleet.n_pods
        self._pod_of = np.arange(self.M) // R

    @property
    def heterogeneous(self) -> bool:
        return self.inv_rate_m is not None

    @property
    def _inv(self) -> jnp.ndarray:
        """The kernels' inverse-rate operand: [M, 3] when heterogeneous,
        the homogeneous [3] vector otherwise."""
        return self.inv_rate_m if self.heterogeneous else self.inv_rates

    # -- locality classes for a request batch ------------------------------

    def _classes(self, locals_: np.ndarray) -> np.ndarray:
        """locals_: [B, r] replica ids holding each request's prefix.
        Returns [B, M] class matrix."""
        B = locals_.shape[0]
        cls = np.full((B, self.M), REMOTE, np.int32)
        for b in range(B):
            pods = np.unique(self._pod_of[locals_[b]])
            cls[b, np.isin(self._pod_of, pods)] = RACK
            cls[b, locals_[b]] = LOCAL
        return cls

    def _sample_candidates(self, cls: np.ndarray, locals_: np.ndarray):
        """3 locals + d_rack + d_remote uniform samples per request."""
        B = cls.shape[0]
        rng = np.random.default_rng(int(jax.random.randint(
            self._next_key(), (), 0, 2**31 - 1)))
        C = locals_.shape[1] + self.pod.d
        idx = np.zeros((B, C), np.int32)
        ccls = np.zeros((B, C), np.int32)
        valid = np.zeros((B, C), bool)
        r = locals_.shape[1]
        idx[:, :r] = locals_
        ccls[:, :r] = LOCAL
        valid[:, :r] = True
        for b in range(B):
            for j, (want, k0, kn) in enumerate(
                    [(RACK, r, r + self.pod.d_rack),
                     (REMOTE, r + self.pod.d_rack, C)]):
                pool = np.where(cls[b] == want)[0]
                if len(pool):
                    take = rng.choice(pool, size=kn - k0)
                    idx[b, k0:kn] = take
                    ccls[b, k0:kn] = want
                    valid[b, k0:kn] = True
        return idx, ccls, valid

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    # -- the routing call ----------------------------------------------------

    def route(self, locals_: np.ndarray) -> np.ndarray:
        """Route a batch of requests; locals_: [B, r] replica ids holding
        each request's prefix.  Returns chosen replica ids [B].

        One fused route_commit launch per batch: request b+1 scores
        against workloads including request b's commit, and Q/W come back
        updated from the same kernel."""
        B = locals_.shape[0]
        cls = self._classes(locals_)
        inv = self._inv
        valid_b = jnp.ones((B,), bool)
        if self.policy == "full":
            # random tie priority per batch: W is lattice-valued, exact
            # ties are routine, and index-order ties hotspot low replicas
            self.Q, self.W, sel, sel_cls, _ = route_commit(
                self.Q, valid_b, inv, cls=jnp.asarray(cls),
                prio=jax.random.permutation(self._next_key(), self.M))
            self.stats.probes += B * self.M
        else:
            idx, ccls, valid = self._sample_candidates(cls, locals_)
            self.Q, self.W, sel, sel_cls, _ = route_commit(
                self.Q, valid_b, inv, cand_idx=jnp.asarray(idx),
                cand_cls=jnp.asarray(ccls), cand_valid=jnp.asarray(valid))
            self.stats.probes += B * idx.shape[1]
        self.stats.decisions += B
        np.add.at(self.stats.routed_by_class, np.asarray(sel_cls), 1)
        return np.asarray(sel)

    def complete(self, replica_ids: np.ndarray, classes: np.ndarray):
        """Mark requests finished (dequeue bookkeeping)."""
        dec = jnp.zeros((self.M, 3), jnp.int32).at[
            jnp.asarray(replica_ids), jnp.asarray(classes)].add(1)
        self.Q = jnp.maximum(self.Q - dec, 0)
        inv = self._inv
        if inv.ndim == 1:
            inv = inv[None, :]
        # same W semantics as kernels.queue_update: dead (non-finite) entries
        # contribute 0 — routing masks dead replicas by rate, never by W.
        self.W = (self.Q.astype(jnp.float32)
                  * jnp.where(jnp.isfinite(inv), inv, 0.0)).sum(-1)
