from .locality import FleetTopology, service_rates
from .router import PodRouter, RouterStats
from .straggler import ShardBalancer
