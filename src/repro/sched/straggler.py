"""Straggler mitigation for the training fleet, built on the paper's core.

At 1000+ nodes, per-step data-shard assignment is a load-balancing problem
with locality: a worker that already holds a shard in host RAM / local
disk is "local", same-pod workers can fetch it over ICI ("rack-local"),
anyone else pulls from the FS ("remote").  A straggling worker is exactly
a low-service-rate server, which is the paper's heterogeneous-server
setting — so the re-balancer *is* Balanced-Pandas-Pod with per-worker
effective workloads W_m scaled by measured worker speed.

O(1) probes per assignment matter here: the coordinator makes
(microbatches x steps) decisions and at fleet scale an O(M) scan per
decision is the scheduler bottleneck the paper quantifies (§IV-C).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WorkerState:
    speed_ema: float = 1.0     # relative throughput (1.0 == healthy)
    backlog: float = 0.0       # outstanding work, in unit-shard cost


class ShardBalancer:
    """Assign data shards to workers each step, avoiding stragglers."""

    def __init__(self, n_workers: int, n_pods: int, d: int = 8,
                 replication: int = 3, ema: float = 0.3, seed: int = 0):
        self.n = n_workers
        self.pod_of = np.arange(n_workers) // max(n_workers // n_pods, 1)
        self.d = d
        self.replication = replication
        self.ema = ema
        self.workers = [WorkerState() for _ in range(n_workers)]
        self.rng = np.random.default_rng(seed)
        self.reassignments = 0
        self.decisions = 0
        self.probes = 0

    def observe(self, worker: int, step_time: float, expected: float):
        """Update the speed EMA from a measured step time."""
        speed = expected / max(step_time, 1e-9)
        w = self.workers[worker]
        w.speed_ema = (1 - self.ema) * w.speed_ema + self.ema * speed

    def _workload(self, w: WorkerState, cls: int) -> float:
        # shard-fetch penalty by locality class (local/ici/fs), then divide
        # by measured speed: a straggler's queue "looks longer".
        fetch = (1.0, 1.5, 3.0)[cls]
        return (w.backlog + fetch) / max(w.speed_ema, 1e-3)

    def assign(self, shard_homes: np.ndarray) -> int:
        """Route one shard; shard_homes: replica ids that host it locally.
        Returns the chosen worker (power-of-d over locals + sampled)."""
        locals_ = np.asarray(shard_homes)
        pods = np.unique(self.pod_of[locals_])
        cand = list(locals_)
        ccls = [0] * len(cand)
        rack_pool = np.where(np.isin(self.pod_of, pods))[0]
        rack_pool = rack_pool[~np.isin(rack_pool, locals_)]
        rem_pool = np.where(~np.isin(self.pod_of, pods))[0]
        if len(rack_pool):
            cand += list(self.rng.choice(rack_pool, size=min(2, len(rack_pool))))
            ccls += [1] * min(2, len(rack_pool))
        if len(rem_pool):
            k = min(self.d - 2, len(rem_pool))
            cand += list(self.rng.choice(rem_pool, size=k))
            ccls += [2] * k
        scores = [self._workload(self.workers[c], cl)
                  for c, cl in zip(cand, ccls)]
        pick = int(np.argmin(scores))
        worker = int(cand[pick])
        if ccls[pick] != 0:
            self.reassignments += 1
        self.workers[worker].backlog += (1.0, 1.5, 3.0)[ccls[pick]]
        self.decisions += 1
        self.probes += len(cand)
        return worker

    def drain(self, dt: float = 1.0):
        """Advance simulated time: workers burn backlog at their speed."""
        for w in self.workers:
            w.backlog = max(0.0, w.backlog - dt * w.speed_ema)
