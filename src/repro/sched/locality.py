"""TPU-fleet locality model for the scheduler (DESIGN.md §2).

Maps the paper's {local, rack-local, remote} onto a serving fleet:
  local      — replica whose HBM prefix-cache already holds the request's
               prefix (no fetch; fastest time-to-first-token),
  rack-local — replica in the same pod: the KV prefix can be fetched over
               ICI from a local replica,
  remote     — replica in another pod: fetch over DCN, or recompute prefill.

Service-rate ratios default to measured-order-of-magnitude constants: a
cache-hit decode ramps immediately (alpha), an ICI fetch costs ~ prefix_bytes
/ 50 GB/s (beta), DCN/recompute ~5x that (gamma) — the same alpha>beta>gamma
structure as the paper's Hadoop measurements [19-21].
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.cluster import Cluster, Rates


@dataclasses.dataclass(frozen=True)
class FleetTopology:
    """n_replicas model replicas spread over n_pods pods."""

    n_replicas: int
    n_pods: int
    replication: int = 3          # prefix-cache copies per hot prefix

    def as_cluster(self) -> Cluster:
        """The paper-core Cluster object: replicas == servers, pods == racks."""
        return Cluster(M=self.n_replicas, K=self.n_pods,
                       n_replicas=self.replication)

    def pod_of(self, r: int) -> int:
        return r // (self.n_replicas // self.n_pods)


def service_rates(prefix_tokens: int = 2048, decode_tokens: int = 256,
                  tok_per_s_hit: float = 50.0) -> Rates:
    """Per-slot completion probabilities for one request class.

    A slot is 1s of replica decode time.  alpha: pure decode after a cache
    hit; beta: + ICI prefix fetch; gamma: + DCN fetch / prefill recompute.
    Ratios follow the up-to-6x locality penalty of [19-21].
    """
    t_hit = decode_tokens / tok_per_s_hit
    t_ici = t_hit * 2.0
    t_dcn = t_hit * 5.0
    return Rates(alpha=min(0.9, 1.0 / t_hit), beta=min(0.9, 1.0 / t_ici),
                 gamma=min(0.9, 1.0 / t_dcn))
